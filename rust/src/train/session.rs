//! Multi-rank training session helper: runs rank trainers on the
//! executor's worker-thread skeleton
//! ([`run_worker_threads`](crate::runtime::executor::run_worker_threads))
//! over a shared transport + engine, runs N steps with barrier-aligned
//! step starts, collects per-step stats, optionally evaluates BLEU at
//! the end.  This is the harness the examples, the live-calibration
//! path, and the integration tests all drive; the engine-free native
//! sibling is [`crate::train::native`].
//!
//! The second half of this module is the **elastic session**
//! ([`run_elastic_session`]): a synthetic data-parallel training loop
//! that survives injected faults.  Each step the group barriers
//! ([`Health::sync_start`]), runs a fallible allreduce over a
//! [`SubTransport`] view of the survivors, and votes
//! ([`Health::commit`]): `Commit` applies the step, `Retry` reruns it
//! after a transient fault, and `Shrink` (a death) re-forms the group
//! at p′ < p and rolls every survivor back to the last checkpoint —
//! the Elastic-Horovod recovery shape, in-process.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::collectives::{self, AllreduceAlgo, TAG_BLOCK};
use crate::coordinator::ExchangeConfig;
use crate::data::{bleu::bleu_smoothed, Corpus, CorpusConfig};
use crate::runtime::executor::{run_elastic, run_worker_threads, RankExit, WorkerFn};
use crate::runtime::health::{ElasticCoord, Group, HealthOpts, Verdict};
use crate::runtime::{Engine, Manifest};
use crate::tensor::AccumStrategy;
use crate::train::checkpoint::Checkpoint;
use crate::train::trainer::{load_artifacts, StepStats, Trainer, TrainerConfig};
use crate::transport::{
    FaultPlan, FaultyTransport, LocalTransport, SubTransport, Transport, TransportKind, WireFormat,
};
use crate::util::rng::Rng;

/// Everything a live multi-rank run produces.
#[derive(Debug)]
pub struct SessionResult {
    /// `[rank][step]`
    pub stats: Vec<Vec<StepStats>>,
    /// BLEU on held-out pairs (rank 0's replica), if eval was requested.
    pub bleu: Option<f64>,
    /// total wall time of the training loop, seconds
    pub wall_secs: f64,
}

impl SessionResult {
    /// Mean loss per step across ranks (they see different shards, so
    /// this is the global batch loss estimate).
    pub fn loss_curve(&self) -> Vec<f32> {
        let steps = self.stats[0].len();
        (0..steps)
            .map(|s| {
                self.stats.iter().map(|r| r[s].loss).sum::<f32>() / self.stats.len() as f32
            })
            .collect()
    }

    pub fn mean_exchange_us(&self) -> f64 {
        let all: Vec<f64> = self
            .stats
            .iter()
            .flat_map(|r| r.iter().map(|s| s.exchange.exec_us as f64))
            .collect();
        all.iter().sum::<f64>() / all.len() as f64
    }

    pub fn peak_accum_bytes(&self) -> u64 {
        self.stats
            .iter()
            .flat_map(|r| r.iter().map(|s| s.exchange.peak_accum_bytes))
            .max()
            .unwrap_or(0)
    }
}

/// Session parameters for [`run_session`].
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub preset: String,
    pub strategy: AccumStrategy,
    pub nranks: usize,
    pub steps: usize,
    pub exchange: ExchangeConfig,
    pub corpus: CorpusConfig,
    pub eval_pairs: usize,
    pub timeline: bool,
    pub seed: u64,
    pub warmup_steps: u64,
    pub lr_scale: f32,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            preset: "tiny".into(),
            strategy: AccumStrategy::SparseAsDense,
            nranks: 2,
            steps: 10,
            exchange: ExchangeConfig::default(),
            corpus: CorpusConfig::default(),
            eval_pairs: 0,
            timeline: false,
            seed: 17,
            warmup_steps: 60,
            lr_scale: 1.0,
        }
    }
}

/// Run a live multi-rank training session end to end, creating a
/// fresh PJRT engine (convenience wrapper over
/// [`run_session_with_engine`] — reuse one engine across sessions to
/// amortize XLA compilation).
pub fn run_session(cfg: &SessionConfig, manifest: &Manifest) -> anyhow::Result<SessionResult> {
    let engine = Engine::start()?;
    run_session_with_engine(cfg, manifest, engine.handle())
}

/// Run a live multi-rank training session on an existing engine.
///
/// Every rank runs as an executor worker thread
/// ([`run_worker_threads`]) with barrier-aligned step starts; rank 0's
/// trainer is handed back out of its thread so the end-of-run BLEU
/// decode can use its replica.  All ranks share the PJRT engine
/// (execution serializes — see `runtime::engine`).  Artifact loading
/// is idempotent, so repeated sessions on one engine compile each HLO
/// once.
pub fn run_session_with_engine(
    cfg: &SessionConfig,
    manifest: &Manifest,
    handle: crate::runtime::EngineHandle,
) -> anyhow::Result<SessionResult> {
    let preset = manifest.preset(&cfg.preset)?;
    anyhow::ensure!(
        cfg.corpus.vocab == preset.config.vocab,
        "corpus vocab {} != preset vocab {}",
        cfg.corpus.vocab,
        preset.config.vocab
    );
    let want_eval = cfg.eval_pairs > 0;
    load_artifacts(&handle, manifest, &cfg.preset, cfg.strategy, want_eval)?;

    let corpus = Corpus::generate(&cfg.corpus);
    let (train_corpus, test_corpus) = if want_eval {
        corpus.split(cfg.eval_pairs)
    } else {
        (corpus.clone(), corpus)
    };
    let init_params = preset.load_params(manifest)?;

    let transport: Arc<LocalTransport> = Arc::new(LocalTransport::new(cfg.nranks));
    let tcfg = TrainerConfig {
        preset: cfg.preset.clone(),
        strategy: cfg.strategy,
        exchange: cfg.exchange,
        warmup_steps: cfg.warmup_steps,
        lr_scale: cfg.lr_scale,
        seed: cfg.seed,
    };

    let mut trainers: Vec<Trainer> = (0..cfg.nranks)
        .map(|rank| {
            Trainer::new(
                &tcfg,
                manifest,
                preset,
                handle.clone(),
                transport.clone(),
                rank,
                train_corpus.clone(),
                init_params.clone(),
            )
        })
        .collect::<anyhow::Result<_>>()?;
    if cfg.timeline {
        trainers[0].enable_timeline();
    }

    let steps = cfg.steps;
    let t0 = std::time::Instant::now();
    type RankDone = anyhow::Result<(usize, Vec<StepStats>, Trainer)>;
    let workers: Vec<WorkerFn<RankDone>> = trainers
        .into_iter()
        .map(|mut tr| {
            Box::new(move |barrier: &std::sync::Barrier| -> RankDone {
                let mut stats = Vec::with_capacity(steps);
                for _ in 0..steps {
                    barrier.wait(); // barrier-aligned step starts
                    stats.push(tr.train_step()?);
                }
                Ok((tr.rank, stats, tr))
            }) as WorkerFn<RankDone>
        })
        .collect();
    let mut all = vec![Vec::new(); cfg.nranks];
    let mut rank0 = None;
    for (slot, joined) in run_worker_threads(workers).into_iter().enumerate() {
        let (rank, stats, tr) =
            joined.map_err(|_| anyhow::anyhow!("rank {slot} thread panicked"))??;
        if rank == 0 {
            rank0 = Some(tr);
        }
        all[rank] = stats;
    }
    let rank0 = rank0.expect("rank 0 finished");
    let wall_secs = t0.elapsed().as_secs_f64();

    let bleu_score = if want_eval {
        let srcs: Vec<Vec<i32>> = test_corpus.pairs.iter().map(|p| p.src.clone()).collect();
        let refs: Vec<Vec<i32>> = test_corpus.pairs.iter().map(|p| p.tgt.clone()).collect();
        let hyps = rank0.greedy_decode(&srcs)?;
        Some(bleu_smoothed(&hyps, &refs))
    } else {
        None
    };

    Ok(SessionResult { stats: all, bleu: bleu_score, wall_secs })
}

// ---------------------------------------------------------------------------
// Elastic session: checkpoint-based recovery under injected faults
// ---------------------------------------------------------------------------

/// Retry budget per step: sync_start adopts the same attempt on every
/// member, so hitting the cap is a collective decision.  The era
/// formula (`epoch * 1024 + attempt`) needs attempt < 1024; 512 is
/// far beyond anything a sub-certain fault rate produces.
pub(crate) const MAX_ATTEMPTS: u64 = 512;

/// Injected budget exhaustion ([`FaultPlan::with_oom`]) that survives
/// this many degraded retries of one step is unrecoverable: the rank
/// self-declares dead so the survivors shrink around it, exactly like
/// a crash.  Kept small — each failed attempt already shrank the
/// segment 4x, so by the fourth the plan is as degraded as it gets.
pub(crate) const OOM_DEATH_ATTEMPTS: u64 = 4;

/// Pipelined-ring segment size for a retry attempt: each failed
/// attempt quarters the segment (floor one element), trading pipeline
/// overlap for a smaller in-flight footprint.  The group-adopted
/// attempt counter from `sync_start` is the lockstep source the ring
/// requires — every member derives the same segment without any extra
/// agreement traffic.  Segment size never changes the per-element
/// reduction order, so degraded retries stay bit-identical.
pub(crate) fn degraded_segment(attempt: u64) -> usize {
    (collectives::ring::DEFAULT_SEGMENT_ELEMS >> (2 * attempt.min(16))).max(1)
}

/// Configuration for [`run_elastic_session`].
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Initial world size (shrinks as ranks die).
    pub nranks: usize,
    /// Training steps to complete (survivors finish all of them, re-
    /// running rolled-back ones as needed).
    pub steps: usize,
    /// Parameter / gradient vector length.
    pub elems: usize,
    /// SGD learning rate (applied to the mean gradient, so the update
    /// stays scale-consistent as the group shrinks).
    pub lr: f32,
    /// Save a checkpoint every N committed steps (0 = only the final
    /// one).  The baseline step-0 checkpoint is always written.
    pub checkpoint_every: usize,
    /// Allreduce algorithm for the gradient exchange.
    pub algo: AllreduceAlgo,
    /// Wire format for the gradient exchange.
    pub wire: WireFormat,
    /// Per-receive timeout inside collectives.
    pub recv_timeout: Duration,
    /// Monitor deadline: a rank silent this long is declared dead.
    /// Must comfortably exceed `recv_timeout` plus one step's work.
    pub heartbeat_deadline: Duration,
    /// Fault plan: link faults wrap the transport in a
    /// [`FaultyTransport`]; kill schedules make ranks exit mid-run;
    /// OOM schedules make a rank's step allocation fail so the group
    /// retries with a degraded plan (and shrinks if it never clears).
    pub faults: FaultPlan,
    /// Checkpoint file path (shared by all ranks — one process, or
    /// worker processes sharing a filesystem).
    pub ckpt_path: PathBuf,
    /// Seed for initial parameters and synthetic gradients.
    pub seed: u64,
    /// Which transport the in-process session runs over (the
    /// multi-process launcher builds its own socket endpoints and
    /// calls [`elastic_worker`] directly).
    pub transport: TransportKind,
}

impl ElasticConfig {
    /// Small fast defaults for tests and the chaos harness.
    pub fn quick(nranks: usize, steps: usize, ckpt_path: PathBuf) -> Self {
        Self {
            nranks,
            steps,
            elems: 2048,
            lr: 0.05,
            checkpoint_every: 2,
            algo: AllreduceAlgo::Ring,
            wire: WireFormat::F32,
            recv_timeout: Duration::from_millis(150),
            heartbeat_deadline: Duration::from_millis(500),
            faults: FaultPlan::none(),
            ckpt_path,
            seed: 42,
            transport: TransportKind::Shm,
        }
    }
}

/// What one surviving rank brings back from an elastic run.
#[derive(Debug, Clone)]
pub struct ElasticOutcome {
    /// Physical rank.
    pub rank: usize,
    /// Final parameter replica (bit-identical across survivors).
    pub params: Vec<f32>,
    /// Steps committed (always `cfg.steps` for a survivor).
    pub steps_done: u64,
    /// Transient-fault retries this rank voted through.
    pub retries: u64,
    /// Checkpoint rollbacks (one per shrink this rank lived through).
    pub rollbacks: u64,
    /// Final group epoch (number of shrinks survived).
    pub final_epoch: u64,
    /// Final group membership.
    pub members: Vec<usize>,
}

/// Everything an elastic run produces.
#[derive(Debug)]
pub struct ElasticReport {
    /// Ranks that finished, ascending rank order.
    pub survivors: Vec<ElasticOutcome>,
    /// Ranks that died per the kill schedule, with the cycle.
    pub died: Vec<(usize, usize)>,
    /// Ranks evicted on a false-positive death declaration.
    pub evicted: Vec<usize>,
    /// Ranks that failed hard, with the reason.
    pub failed: Vec<(usize, String)>,
}

impl ElasticReport {
    /// The final group membership (from any survivor).
    pub fn final_members(&self) -> Vec<usize> {
        self.survivors.first().map(|s| s.members.clone()).unwrap_or_default()
    }

    /// Assert every survivor finished every step, agrees on the final
    /// membership/epoch, and holds **bit-identical** parameters — the
    /// elastic analogue of the executor's lockstep invariant.
    pub fn assert_survivors_agree(&self, steps: u64) {
        assert!(!self.survivors.is_empty(), "no survivors");
        let first = &self.survivors[0];
        let bits: Vec<u32> = first.params.iter().map(|x| x.to_bits()).collect();
        for s in &self.survivors {
            assert_eq!(s.steps_done, steps, "rank {} stopped early", s.rank);
            assert_eq!(s.members, first.members, "rank {} membership", s.rank);
            assert_eq!(s.final_epoch, first.final_epoch, "rank {} epoch", s.rank);
            let sb: Vec<u32> = s.params.iter().map(|x| x.to_bits()).collect();
            assert_eq!(sb, bits, "rank {} params diverged from rank {}", s.rank, first.rank);
        }
    }
}

/// Deterministic synthetic gradient for (physical rank, step): the
/// closed form lets a rolled-back survivor regenerate exactly the
/// gradient it contributed before the fault — and lets an external
/// oracle (the cross-process tests, the launcher's reference pass)
/// replay the whole run without sharing any state with the workers.
pub fn grad_vec(rank: usize, step: u64, elems: usize, seed: u64) -> Vec<f32> {
    (0..elems as u64)
        .map(|i| {
            let h = rank as u64 * 31 + step * 17 + i * 7 + seed * 13 + 3;
            (h % 23) as f32 * 0.25 - 2.75
        })
        .collect()
}

/// Deterministic initial parameters (identical on every rank).
pub fn init_params(elems: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0xE1A5);
    (0..elems).map(|_| (rng.gen_range(0, 2001) as f32 - 1000.0) / 1000.0).collect()
}

/// Write the step-0 baseline checkpoint for `cfg` — the very first
/// shrink always has something to roll back to.  [`run_elastic_session`]
/// does this itself; a multi-process launcher calls it once *before*
/// spawning workers (so no boot fence is needed).
pub fn write_baseline_checkpoint(cfg: &ElasticConfig) -> anyhow::Result<()> {
    let zeros = vec![0.0f32; cfg.elems];
    Checkpoint {
        step: 0,
        params: init_params(cfg.elems, cfg.seed),
        adam_m: zeros.clone(),
        adam_v: zeros,
    }
    .save(&cfg.ckpt_path)?;
    Ok(())
}

/// Run a fault-tolerant synthetic training session: one OS thread per
/// rank over a [`ShmTransport`] (wrapped in a [`FaultyTransport`] when
/// the plan injects link faults), a health monitor, and checkpoint-
/// based shrink recovery.  Returns once every rank has exited.
///
/// Guarantees (asserted by `tests/chaos.rs` and the `repro chaos`
/// gate): the run terminates — no deadlock — even when ranks are
/// killed mid-step; survivors complete all `cfg.steps`; and their
/// final parameters are bit-identical, because every survivor sees
/// the same verdict sequence, the same group epochs, and collectives
/// that produce cross-rank-identical bits.
pub fn run_elastic_session(cfg: &ElasticConfig) -> anyhow::Result<ElasticReport> {
    anyhow::ensure!(cfg.nranks >= 1, "need at least one rank");
    anyhow::ensure!(cfg.steps >= 1, "need at least one step");
    anyhow::ensure!(cfg.elems >= 1, "need at least one element");

    // Baseline checkpoint (step 0) before any worker starts: the very
    // first shrink always has something to roll back to.
    write_baseline_checkpoint(cfg)?;

    let base: Arc<dyn Transport> = cfg.transport.create(cfg.nranks)?;
    let transport: Arc<dyn Transport> = if cfg.faults.has_link_faults() {
        Arc::new(FaultyTransport::new(base, cfg.faults.clone()))
    } else {
        base
    };

    let opts = HealthOpts {
        heartbeat_deadline: cfg.heartbeat_deadline,
        poll: Duration::from_millis(10),
    };
    let cfg_arc = Arc::new(cfg.clone());
    let run = run_elastic(transport, opts, move |rank, t, health| {
        elastic_worker(rank, t, &*health, &cfg_arc)
    });

    let mut report = ElasticReport {
        survivors: Vec::new(),
        died: Vec::new(),
        evicted: Vec::new(),
        failed: Vec::new(),
    };
    for (rank, exit) in run.exits.into_iter().enumerate() {
        match exit {
            RankExit::Finished(o) => report.survivors.push(o),
            RankExit::Died { cycle } => report.died.push((rank, cycle)),
            RankExit::Evicted => report.evicted.push(rank),
            RankExit::Failed(msg) => report.failed.push((rank, msg)),
        }
    }
    Ok(report)
}

/// The per-rank body of the elastic loop (see module docs for the
/// protocol; every protocol error means this rank was evicted).
///
/// Written against [`ElasticCoord`], so the identical
/// step/retry/shrink/rollback loop runs over in-process [`Health`]
/// rounds (threaded ranks, [`run_elastic_session`]) and over
/// [`WireCoord`](crate::runtime::WireCoord) control messages (worker
/// processes — the launcher builds a socket endpoint + `WireCoord`
/// per process and calls this directly).
///
/// [`Health`]: crate::runtime::Health
pub fn elastic_worker(
    rank: usize,
    transport: Arc<dyn Transport>,
    coord: &dyn ElasticCoord,
    cfg: &ElasticConfig,
) -> RankExit<ElasticOutcome> {
    let kill_cycle = cfg.faults.kill_cycle(rank);
    let mut group = Group::world(cfg.nranks);
    let mut params = init_params(cfg.elems, cfg.seed);
    let mut step: u64 = 0;
    let mut attempt: u64 = 0;
    let mut seq: u64 = 0;
    let mut retries: u64 = 0;
    let mut rollbacks: u64 = 0;
    let steps = cfg.steps as u64;

    while step < steps {
        // Simulated crash: stop beating and exit. The monitor will
        // declare this rank dead exactly as it would a real one.
        if kill_cycle == Some(step as usize) {
            return RankExit::Died { cycle: step as usize };
        }
        coord.beat(rank);

        // Cycle-start barrier: adopt the group's maximum attempt so a
        // rank whose last collective failed and one whose succeeded
        // re-enter the step aligned on the same era.
        attempt = match coord.sync_start(rank, &group, seq, attempt) {
            Ok(a) => a,
            Err(_) => return RankExit::Evicted,
        };
        seq += 1;
        if attempt >= MAX_ATTEMPTS {
            // A collective decision: every member adopted this attempt,
            // so every member fails together. Self-declare dead so any
            // straggler blocked on us unblocks immediately.
            coord.declare_dead(rank);
            transport.mark_dead(rank);
            return RankExit::Failed(format!(
                "step {step}: retry budget exhausted after {attempt} attempts"
            ));
        }

        // Injected budget exhaustion: this rank's scratch acquire
        // "fails" while the schedule still covers the attempt.  The
        // step is skipped and voted down — the group retries it with a
        // degraded (smaller-segment) plan, the graceful-degradation
        // ladder for memory faults.
        let oom = cfg.faults.oom_attempts(rank, step as usize) as u64 > attempt;
        if oom && attempt >= OOM_DEATH_ATTEMPTS {
            // Pressure that degradation cannot relieve: leave the
            // group like a crash so the survivors shrink around us.
            coord.declare_dead(rank);
            transport.mark_dead(rank);
            return RankExit::Failed(format!(
                "step {step}: memory budget exhausted after {attempt} degraded retries"
            ));
        }

        // Dense view of the survivors, in a tag era unique to this
        // (epoch, attempt) so stale traffic from aborted collectives
        // can never cross-match.
        let era = group.epoch * 1024 + attempt;
        let sub = SubTransport::new(transport.clone(), group.members.clone(), era);
        let dense = group.dense_rank(rank).expect("member of own group");

        // The collective runs on a scratch buffer; `params` is only
        // touched on Commit, so Retry/Shrink never poison the model.
        let mut buf = grad_vec(rank, step, cfg.elems, cfg.seed);
        let ok = if oom || coord.group_impaired(&group) {
            // allocation failed (nothing was sent), or a member is
            // already known dead: the step is doomed, skip straight to
            // the vote
            false
        } else {
            collectives::try_allreduce_wire_seg(
                &sub,
                dense,
                &mut buf,
                cfg.algo,
                step * TAG_BLOCK,
                cfg.wire,
                degraded_segment(attempt),
                Some(cfg.recv_timeout),
            )
            .is_ok()
        };
        coord.beat(rank);

        let verdict = match coord.commit(rank, &group, seq, ok) {
            Ok(v) => v,
            Err(_) => return RankExit::Evicted,
        };
        seq += 1;

        match verdict {
            Verdict::Commit => {
                // buf holds the sum over the current members; apply the
                // mean-gradient SGD step so shrinks stay scale-stable
                let scale = cfg.lr / group.members.len() as f32;
                for (p, g) in params.iter_mut().zip(&buf) {
                    *p -= scale * g;
                }
                step += 1;
                attempt = 0;
                let at_interval =
                    cfg.checkpoint_every > 0 && step % cfg.checkpoint_every as u64 == 0;
                if at_interval || step == steps {
                    if rank == group.leader() {
                        let zeros = vec![0.0f32; cfg.elems];
                        let ck = Checkpoint {
                            step,
                            params: params.clone(),
                            adam_m: zeros.clone(),
                            adam_v: zeros,
                        };
                        if let Err(e) = ck.save(&cfg.ckpt_path) {
                            coord.declare_dead(rank);
                            transport.mark_dead(rank);
                            return RankExit::Failed(format!("checkpoint save: {e}"));
                        }
                    }
                    // fence: nobody races past a checkpoint that is
                    // not yet durably on disk (a shrink during the
                    // next step must find it)
                    if coord.sync_point(rank, &group, seq).is_err() {
                        return RankExit::Evicted;
                    }
                    seq += 1;
                }
            }
            Verdict::Retry => {
                attempt += 1;
                retries += 1;
            }
            Verdict::Shrink => {
                group = match coord.regroup(rank, &group) {
                    Ok(g) => g,
                    Err(_) => return RankExit::Evicted,
                };
                seq = 0;
                attempt = 0;
                rollbacks += 1;
                match Checkpoint::load(&cfg.ckpt_path) {
                    Ok(ck) => {
                        step = ck.step;
                        params = ck.params;
                    }
                    Err(e) => {
                        coord.declare_dead(rank);
                        transport.mark_dead(rank);
                        return RankExit::Failed(format!("checkpoint load: {e}"));
                    }
                }
            }
        }
    }

    RankExit::Finished(ElasticOutcome {
        rank,
        params,
        steps_done: step,
        retries,
        rollbacks,
        final_epoch: group.epoch,
        members: group.members,
    })
}

#[cfg(test)]
mod elastic_tests {
    use super::*;

    fn tmp_ckpt(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "densefold_elastic_{name}_{}.ckpt",
            std::process::id()
        ))
    }

    #[test]
    fn fault_free_run_finishes_and_agrees() {
        let path = tmp_ckpt("clean");
        let cfg = ElasticConfig::quick(3, 4, path.clone());
        let report = run_elastic_session(&cfg).unwrap();
        assert!(report.died.is_empty() && report.evicted.is_empty() && report.failed.is_empty());
        report.assert_survivors_agree(4);
        assert_eq!(report.final_members(), vec![0, 1, 2]);
        assert_eq!(report.survivors[0].rollbacks, 0);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fault_free_matches_single_rank_math() {
        // p ranks averaging their gradients must match a by-hand SGD
        // trace of the same closed-form gradients
        let path = tmp_ckpt("math");
        let cfg = ElasticConfig::quick(2, 3, path.clone());
        let report = run_elastic_session(&cfg).unwrap();
        report.assert_survivors_agree(3);
        let mut expect = init_params(cfg.elems, cfg.seed);
        for step in 0..3u64 {
            let mut sum = vec![0.0f32; cfg.elems];
            for r in 0..2 {
                for (s, g) in sum.iter_mut().zip(grad_vec(r, step, cfg.elems, cfg.seed)) {
                    *s += g;
                }
            }
            for (p, g) in expect.iter_mut().zip(&sum) {
                *p -= cfg.lr / 2.0 * g;
            }
        }
        let got: Vec<u32> = report.survivors[0].params.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> = expect.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn single_rank_session_runs() {
        let path = tmp_ckpt("single");
        let cfg = ElasticConfig::quick(1, 3, path.clone());
        let report = run_elastic_session(&cfg).unwrap();
        report.assert_survivors_agree(3);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn injected_oom_retries_degraded_and_stays_bit_exact() {
        // rank 1's step-2 allocation fails twice: the group votes two
        // retries (each with a 4x-smaller ring segment), then commits.
        // Degradation must be invisible in the bits — the run ends
        // with exactly the fault-free parameters.
        let path = tmp_ckpt("oom_retry");
        let mut cfg = ElasticConfig::quick(3, 4, path.clone());
        cfg.algo = AllreduceAlgo::RingPipelined; // exercise the segment ladder
        cfg.faults = FaultPlan::none().with_oom(1, 2, 2);
        let report = run_elastic_session(&cfg).unwrap();
        assert!(report.died.is_empty() && report.failed.is_empty(), "{report:?}");
        report.assert_survivors_agree(4);
        assert_eq!(report.final_members(), vec![0, 1, 2]);
        for s in &report.survivors {
            assert!(s.retries >= 2, "rank {} saw {} retries", s.rank, s.retries);
            assert_eq!(s.rollbacks, 0, "retries must not roll back");
        }

        let ref_path = tmp_ckpt("oom_retry_ref");
        let mut clean = cfg.clone();
        clean.ckpt_path = ref_path.clone();
        clean.faults = FaultPlan::none();
        let clean_report = run_elastic_session(&clean).unwrap();
        let got: Vec<u32> =
            report.survivors[0].params.iter().map(|x| x.to_bits()).collect();
        let want: Vec<u32> =
            clean_report.survivors[0].params.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want, "degraded retries changed the training bits");
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(ref_path);
    }

    #[test]
    fn persistent_oom_shrinks_the_group_replayably() {
        // rank 2's step-1 allocation never clears: after the degraded
        // retries are exhausted it self-declares dead, the survivors
        // shrink to [0, 1], roll back, and finish — and an identical
        // rerun produces identical bits (the schedule is declarative).
        let run_once = |tag: &str| {
            let path = tmp_ckpt(tag);
            let mut cfg = ElasticConfig::quick(3, 4, path.clone());
            cfg.faults = FaultPlan::none().with_oom(2, 1, 64);
            let report = run_elastic_session(&cfg).unwrap();
            let _ = std::fs::remove_file(path);
            report
        };
        let report = run_once("oom_shrink_a");
        report.assert_survivors_agree(4);
        assert_eq!(report.final_members(), vec![0, 1]);
        assert_eq!(report.failed.len(), 1, "{report:?}");
        assert_eq!(report.failed[0].0, 2);
        assert!(
            report.failed[0].1.contains("memory budget exhausted"),
            "{}",
            report.failed[0].1
        );
        for s in &report.survivors {
            assert!(s.rollbacks >= 1, "shrink must roll back (rank {})", s.rank);
        }

        let replay = run_once("oom_shrink_b");
        let a: Vec<u32> = report.survivors[0].params.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = replay.survivors[0].params.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "OOM schedule must replay bit-exactly");
    }
}
