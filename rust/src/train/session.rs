//! Multi-rank training session helper: spawns rank threads over a
//! shared transport + engine, runs N steps, collects per-step stats,
//! optionally evaluates BLEU at the end.  This is the harness the
//! examples, the live-calibration path, and the integration tests all
//! drive.

use std::sync::Arc;

use crate::coordinator::ExchangeConfig;
use crate::data::{bleu::bleu_smoothed, Corpus, CorpusConfig};
use crate::runtime::{Engine, Manifest};
use crate::tensor::AccumStrategy;
use crate::transport::LocalTransport;
use crate::train::trainer::{load_artifacts, StepStats, Trainer, TrainerConfig};

/// Everything a live multi-rank run produces.
#[derive(Debug)]
pub struct SessionResult {
    /// `[rank][step]`
    pub stats: Vec<Vec<StepStats>>,
    /// BLEU on held-out pairs (rank 0's replica), if eval was requested.
    pub bleu: Option<f64>,
    /// total wall time of the training loop, seconds
    pub wall_secs: f64,
}

impl SessionResult {
    /// Mean loss per step across ranks (they see different shards, so
    /// this is the global batch loss estimate).
    pub fn loss_curve(&self) -> Vec<f32> {
        let steps = self.stats[0].len();
        (0..steps)
            .map(|s| {
                self.stats.iter().map(|r| r[s].loss).sum::<f32>() / self.stats.len() as f32
            })
            .collect()
    }

    pub fn mean_exchange_us(&self) -> f64 {
        let all: Vec<f64> = self
            .stats
            .iter()
            .flat_map(|r| r.iter().map(|s| s.exchange.exec_us as f64))
            .collect();
        all.iter().sum::<f64>() / all.len() as f64
    }

    pub fn peak_accum_bytes(&self) -> u64 {
        self.stats
            .iter()
            .flat_map(|r| r.iter().map(|s| s.exchange.peak_accum_bytes))
            .max()
            .unwrap_or(0)
    }
}

/// Session parameters for [`run_session`].
#[derive(Debug, Clone)]
pub struct SessionConfig {
    pub preset: String,
    pub strategy: AccumStrategy,
    pub nranks: usize,
    pub steps: usize,
    pub exchange: ExchangeConfig,
    pub corpus: CorpusConfig,
    pub eval_pairs: usize,
    pub timeline: bool,
    pub seed: u64,
    pub warmup_steps: u64,
    pub lr_scale: f32,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            preset: "tiny".into(),
            strategy: AccumStrategy::SparseAsDense,
            nranks: 2,
            steps: 10,
            exchange: ExchangeConfig::default(),
            corpus: CorpusConfig::default(),
            eval_pairs: 0,
            timeline: false,
            seed: 17,
            warmup_steps: 60,
            lr_scale: 1.0,
        }
    }
}

/// Run a live multi-rank training session end to end, creating a
/// fresh PJRT engine (convenience wrapper over
/// [`run_session_with_engine`] — reuse one engine across sessions to
/// amortize XLA compilation).
pub fn run_session(cfg: &SessionConfig, manifest: &Manifest) -> anyhow::Result<SessionResult> {
    let engine = Engine::start()?;
    run_session_with_engine(cfg, manifest, engine.handle())
}

/// Run a live multi-rank training session on an existing engine.
///
/// Rank 0's trainer stays on the caller thread (so its timeline can be
/// inspected); other ranks run on spawned threads.  All ranks share
/// the PJRT engine (execution serializes — see `runtime::engine`).
/// Artifact loading is idempotent, so repeated sessions on one engine
/// compile each HLO once.
pub fn run_session_with_engine(
    cfg: &SessionConfig,
    manifest: &Manifest,
    handle: crate::runtime::EngineHandle,
) -> anyhow::Result<SessionResult> {
    let preset = manifest.preset(&cfg.preset)?;
    anyhow::ensure!(
        cfg.corpus.vocab == preset.config.vocab,
        "corpus vocab {} != preset vocab {}",
        cfg.corpus.vocab,
        preset.config.vocab
    );
    let want_eval = cfg.eval_pairs > 0;
    load_artifacts(&handle, manifest, &cfg.preset, cfg.strategy, want_eval)?;

    let corpus = Corpus::generate(&cfg.corpus);
    let (train_corpus, test_corpus) = if want_eval {
        corpus.split(cfg.eval_pairs)
    } else {
        (corpus.clone(), corpus)
    };
    let init_params = preset.load_params(manifest)?;

    let transport: Arc<LocalTransport> = Arc::new(LocalTransport::new(cfg.nranks));
    let tcfg = TrainerConfig {
        preset: cfg.preset.clone(),
        strategy: cfg.strategy,
        exchange: cfg.exchange,
        warmup_steps: cfg.warmup_steps,
        lr_scale: cfg.lr_scale,
        seed: cfg.seed,
    };

    let mut trainers: Vec<Trainer> = (0..cfg.nranks)
        .map(|rank| {
            Trainer::new(
                &tcfg,
                manifest,
                preset,
                handle.clone(),
                transport.clone(),
                rank,
                train_corpus.clone(),
                init_params.clone(),
            )
        })
        .collect::<anyhow::Result<_>>()?;
    if cfg.timeline {
        trainers[0].enable_timeline();
    }

    let steps = cfg.steps;
    let t0 = std::time::Instant::now();
    let mut rank0 = trainers.remove(0);
    let handles: Vec<_> = trainers
        .into_iter()
        .map(|mut tr| {
            std::thread::spawn(move || -> anyhow::Result<(usize, Vec<StepStats>)> {
                let mut stats = Vec::with_capacity(steps);
                for _ in 0..steps {
                    stats.push(tr.train_step()?);
                }
                Ok((tr.rank, stats))
            })
        })
        .collect();
    let mut rank0_stats = Vec::with_capacity(steps);
    for _ in 0..steps {
        rank0_stats.push(rank0.train_step()?);
    }
    let mut all = vec![Vec::new(); cfg.nranks];
    all[0] = rank0_stats;
    for h in handles {
        let (rank, stats) = h.join().map_err(|_| anyhow::anyhow!("rank thread panicked"))??;
        all[rank] = stats;
    }
    let wall_secs = t0.elapsed().as_secs_f64();

    let bleu_score = if want_eval {
        let srcs: Vec<Vec<i32>> = test_corpus.pairs.iter().map(|p| p.src.clone()).collect();
        let refs: Vec<Vec<i32>> = test_corpus.pairs.iter().map(|p| p.tgt.clone()).collect();
        let hyps = rank0.greedy_decode(&srcs)?;
        Some(bleu_smoothed(&hyps, &refs))
    } else {
        None
    };

    Ok(SessionResult { stats: all, bleu: bleu_score, wall_secs })
}
