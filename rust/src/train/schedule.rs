//! Noam learning-rate schedule (Vaswani et al. §5.3) with linear
//! warmup — the transformer standard the paper's hyper-parameter
//! settings ([15, 12] in the paper) build on.  Large-batch runs scale
//! the base rate, following Ott et al.'s large-batch recipe.

#[derive(Debug, Clone, Copy)]
pub struct NoamSchedule {
    pub d_model: usize,
    pub warmup_steps: u64,
    /// multiplicative scale on top of the Noam curve (≈ linear batch
    /// scaling in the paper's large-batch experiments)
    pub scale: f32,
}

impl NoamSchedule {
    pub fn new(d_model: usize, warmup_steps: u64, scale: f32) -> Self {
        assert!(warmup_steps > 0);
        Self { d_model, warmup_steps, scale }
    }

    /// Learning rate at 1-based step `t`.
    pub fn lr(&self, t: u64) -> f32 {
        let t = t.max(1) as f32;
        let w = self.warmup_steps as f32;
        let base = (self.d_model as f32).powf(-0.5);
        self.scale * base * (t.powf(-0.5)).min(t * w.powf(-1.5))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_increases_then_decays() {
        let s = NoamSchedule::new(512, 4000, 1.0);
        assert!(s.lr(1) < s.lr(2000));
        assert!(s.lr(2000) < s.lr(4000));
        assert!(s.lr(4000) > s.lr(16000));
    }

    #[test]
    fn peak_at_warmup_boundary() {
        let s = NoamSchedule::new(512, 1000, 1.0);
        let peak = s.lr(1000);
        for t in [1u64, 10, 500, 999, 1001, 2000, 100_000] {
            assert!(s.lr(t) <= peak + 1e-9, "t={t}");
        }
    }

    #[test]
    fn linear_during_warmup() {
        let s = NoamSchedule::new(256, 1000, 1.0);
        let r = s.lr(500) / s.lr(250);
        assert!((r - 2.0).abs() < 1e-4, "ratio {r}");
    }

    #[test]
    fn inverse_sqrt_after_warmup() {
        let s = NoamSchedule::new(256, 100, 1.0);
        let r = s.lr(10_000) / s.lr(40_000);
        assert!((r - 2.0).abs() < 1e-3, "ratio {r}");
    }

    #[test]
    fn scale_multiplies() {
        let a = NoamSchedule::new(512, 4000, 1.0);
        let b = NoamSchedule::new(512, 4000, 2.0);
        assert!((b.lr(123) / a.lr(123) - 2.0).abs() < 1e-6);
    }
}
