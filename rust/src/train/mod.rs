//! Data-parallel training runtime (Layer 3 driver).
//!
//! Each rank owns a parameter replica, runs the AOT-compiled training
//! step through the PJRT engine, exchanges gradients through the
//! Horovod-style coordinator under a chosen
//! [`crate::tensor::AccumStrategy`], and applies Adam with the
//! transformer (Noam) LR schedule.  The strategy decides which HLO
//! artifact runs and how the tied-embedding gradient is locally
//! accumulated — reproducing the exact TF/Horovod division of labour
//! the paper analyses.

pub mod checkpoint;
pub mod native;
pub mod optimizer;
pub mod schedule;
pub mod session;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use native::{
    native_elastic_oracle, run_native_elastic_session, run_native_session, NativeElasticConfig,
    NativeSessionResult, NativeTrainConfig,
};
pub use optimizer::Adam;
pub use schedule::NoamSchedule;
pub use session::{
    elastic_worker, run_elastic_session, run_session, run_session_with_engine,
    write_baseline_checkpoint, ElasticConfig, ElasticOutcome, ElasticReport, SessionConfig,
    SessionResult,
};
pub use trainer::{StepStats, Trainer, TrainerConfig};
