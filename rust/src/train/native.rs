//! Engine-free end-to-end training: the native model on the executor.
//!
//! This is the `repro train` path — the layer that finally runs the
//! paper's *workload* (NMT training steps) through every subsystem the
//! earlier PRs built, with no PJRT/XLA dependency:
//!
//! ```text
//! run_native_session
//!   └─ runtime::executor::run_worker_threads      (one thread per rank)
//!        rank r, step s:  barrier ──────────────── aligned step starts
//!          for j in 0..accum:                      gradient accumulation
//!            batch   = batcher.batch_at(m)         m = s·(k·p) + j·p + r
//!            micro   = model.forward_backward()    tied-embedding grads
//!            tensor::accumulate(micro, strategy)   Alg.1 / Listing 1 / Alg.2
//!            acc    += micro                       pooled f32 buffers
//!          GradExchange::exchange(acc)             policy→densify→fused
//!          Adam(params, sum / (p·k))               one combined scale
//!          pool.release(outs)                      buffer recycling
//! ```
//!
//! This is the Ott et al. (*Scaling NMT*, 1806.00187) recipe on top of
//! the paper's core: large effective batches via local gradient
//! accumulation (`--accum`), reduced-precision comms via the 16-bit
//! wire (`--wire fp16|bf16`), one exchange per effective batch.
//!
//! ## Determinism contract (what `rust/tests/train.rs` asserts)
//!
//! Micro-batch `m = step·(accum·p) + j·p + rank` is a *global* index:
//! p=k/accum=1 enumerates exactly the micros of p=1/accum=k, and both
//! sum them in ascending-`m` order — locally (fresh zeroed accumulator
//! `+=` each finished micro gradient, micro order) or across ranks
//! (the `Naive` allreduce's rank-order root sum).  With the f32 wire
//! the two summation sequences are the same f32 additions, so loss
//! trajectories and final parameters are **bit-identical** across the
//! split — and across local/shm/socket transports, which all run the
//! same deterministic collectives.  The exchange runs with
//! `average = false`; the trainer applies the single combined
//! `1/(p·accum)` scale (dividing by p then by k would round
//! differently).
//!
//! The second half is the **native elastic session**: the
//! checkpoint/shrink protocol of [`super::session::elastic_worker`]
//! driving real model gradients (SGD), with a closed-form oracle
//! ([`native_elastic_oracle`]) that replays kill-a-rank runs exactly.

use std::path::PathBuf;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use crate::collectives::{self, AllreduceAlgo, TAG_BLOCK};
use crate::coordinator::{ExchangeConfig, ExchangeReport, GradExchange, NamedGrad};
use crate::data::{bleu::bleu_smoothed, Batch, Batcher, Corpus, CorpusConfig};
use crate::model::native::NativeModel;
use crate::runtime::executor::{run_elastic, run_worker_threads, RankExit, WorkerFn};
use crate::runtime::health::{ElasticCoord, Group, HealthOpts, Verdict};
use crate::tensor::{accumulate, AccumStrategy, DenseTensor, Grad, IndexedSlices};
use crate::train::checkpoint::Checkpoint;
use crate::train::optimizer::{Adam, AdamConfig};
use crate::train::session::{
    degraded_segment, ElasticOutcome, ElasticReport, MAX_ATTEMPTS, OOM_DEATH_ATTEMPTS,
};
use crate::transport::pool::PooledBuffers;
use crate::transport::{
    FaultPlan, FaultyTransport, MemoryBudget, PoolStats, SubTransport, Transport, TransportKind,
    WireFormat,
};

/// Salt mixed into the session seed for the batcher's shared shuffle,
/// so corpus generation and batch order draw from distinct streams.
const BATCH_SEED_SALT: u64 = 0xBA7C;

/// Configuration of a native training session ([`run_native_session`]).
#[derive(Debug, Clone)]
pub struct NativeTrainConfig {
    /// Data-parallel ranks (one executor worker thread each).
    pub nranks: usize,
    /// Optimizer steps (one exchange per step).
    pub steps: usize,
    /// Micro-batches accumulated locally per step (k ≥ 1); the
    /// effective batch is `nranks · accum · batch.0` rows.
    pub accum: usize,
    /// Hidden width of the native model (vocab comes from `corpus`).
    pub d_model: usize,
    /// Batch shape `(b, ss, st)`.
    pub batch: (usize, usize, usize),
    /// Adam learning rate (applied to the `1/(p·accum)`-scaled sum).
    pub lr: f32,
    /// Seed for parameters and batch order (corpus has its own seed).
    pub seed: u64,
    /// Local tied-gradient accumulation strategy (the paper's axis).
    pub strategy: AccumStrategy,
    /// Exchange engine configuration.  `average` is overridden to
    /// `false` — see the module docs' determinism contract.
    pub exchange: ExchangeConfig,
    /// Transport the ranks exchange over.
    pub transport: TransportKind,
    /// Synthetic corpus (its `vocab` sizes the model's embedding).
    pub corpus: CorpusConfig,
    /// Per-process memory budget; transports, exchange arenas, *and*
    /// the accumulator pools all charge it when set.
    pub budget_bytes: Option<u64>,
    /// Held-out pairs for an end-of-run greedy-decode BLEU (0 = skip).
    pub eval_pairs: usize,
    /// Record per-step pre/post-exchange flat gradients (before the
    /// `1/(p·accum)` scale) — the wire-error proptest's raw material.
    pub trace_grads: bool,
}

impl Default for NativeTrainConfig {
    fn default() -> Self {
        Self {
            nranks: 2,
            steps: 8,
            accum: 1,
            d_model: 16,
            batch: (4, 8, 8),
            lr: 0.01,
            seed: 17,
            strategy: AccumStrategy::SparseAsDense,
            exchange: ExchangeConfig::default(),
            transport: TransportKind::Shm,
            corpus: CorpusConfig { vocab: 64, n_pairs: 256, ..Default::default() },
            budget_bytes: None,
            eval_pairs: 0,
            trace_grads: false,
        }
    }
}

/// Pre/post-exchange flat gradients for one step (params-shaped,
/// recorded before the `1/(p·accum)` scale) — lets the proptests
/// compute exact f64 cross-rank sums and bound the wire error.
#[derive(Debug, Clone)]
pub struct GradTrace {
    /// This rank's locally accumulated gradient, densified.
    pub pre: Vec<f32>,
    /// The exchanged (summed) gradient, densified.
    pub post: Vec<f32>,
}

/// One rank's record of one optimizer step.
#[derive(Debug, Clone)]
pub struct NativeStepTrace {
    /// Per-micro un-normalized loss sums, local micro order.
    pub micro_loss: Vec<f32>,
    /// Per-micro non-pad label counts, local micro order.
    pub micro_pos: Vec<usize>,
    /// Real (non-pad) tokens this rank pushed through this step.
    pub tokens: usize,
    /// Forward/backward + accumulate + optimizer wall time, µs.
    pub compute_us: u64,
    /// `GradExchange::exchange` wall time, µs.
    pub exchange_us: u64,
    /// The exchange engine's own report for this step's cycle.
    pub report: ExchangeReport,
}

/// Everything one rank brings back from a native session.
#[derive(Debug, Clone)]
pub struct NativeRankResult {
    /// Physical rank.
    pub rank: usize,
    /// Per-step records.
    pub steps: Vec<NativeStepTrace>,
    /// Final parameter replica (bit-identical across ranks).
    pub params: Vec<f32>,
    /// Accumulator-pool counters (recycling evidence: `allocated`
    /// stays flat across steady-state steps).
    pub pool_stats: PoolStats,
    /// Per-step gradient traces (empty unless `trace_grads`).
    pub grad_trace: Vec<GradTrace>,
}

/// Everything a native session produces.
#[derive(Debug)]
pub struct NativeSessionResult {
    /// Per-rank outcomes, index = rank.
    pub per_rank: Vec<NativeRankResult>,
    /// Global per-step mean loss, summed in ascending global-micro
    /// order (bit-identical across the p/accum split — module docs).
    pub loss_curve: Vec<f32>,
    /// Wall time of the training loop, seconds.
    pub wall_secs: f64,
    /// Smoothed BLEU of rank 0's replica on the held-out pairs.
    pub bleu: Option<f64>,
    /// Ranks and accumulation factor of the run (for reporting).
    pub nranks: usize,
    /// Micro-batches per step per rank.
    pub accum: usize,
}

impl NativeSessionResult {
    /// Total real tokens processed across ranks and steps.
    pub fn total_tokens(&self) -> u64 {
        self.per_rank
            .iter()
            .flat_map(|r| r.steps.iter().map(|s| s.tokens as u64))
            .sum()
    }

    /// End-to-end training throughput.
    pub fn tokens_per_sec(&self) -> f64 {
        self.total_tokens() as f64 / self.wall_secs.max(1e-9)
    }

    /// Mean per-step exchange time across ranks, µs.
    pub fn mean_exchange_us(&self) -> f64 {
        mean(self.per_rank.iter().flat_map(|r| r.steps.iter().map(|s| s.exchange_us as f64)))
    }

    /// Mean per-step compute (forward/backward + optimizer) time, µs.
    pub fn mean_compute_us(&self) -> f64 {
        mean(self.per_rank.iter().flat_map(|r| r.steps.iter().map(|s| s.compute_us as f64)))
    }

    /// Peak exchange-side accumulation bytes across ranks/steps.
    pub fn peak_accum_bytes(&self) -> u64 {
        self.per_rank
            .iter()
            .flat_map(|r| r.steps.iter().map(|s| s.report.peak_accum_bytes))
            .max()
            .unwrap_or(0)
    }

    /// Assert every rank ended with bit-identical parameters — the
    /// data-parallel lockstep invariant, end to end through the model.
    pub fn assert_ranks_agree(&self) {
        let first: Vec<u32> = self.per_rank[0].params.iter().map(|x| x.to_bits()).collect();
        for r in &self.per_rank[1..] {
            let bits: Vec<u32> = r.params.iter().map(|x| x.to_bits()).collect();
            assert_eq!(bits, first, "rank {} params diverged from rank 0", r.rank);
        }
    }
}

fn mean(it: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = it.collect();
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

/// Global per-step loss: sum the per-micro loss sums in ascending
/// global-micro order (`m = step·(accum·p) + j·p + rank`, so iterate
/// `mm = j·p + rank` ascending), divide by the total label count once.
/// The identical f32 addition sequence is produced by p=k/accum=1
/// (rank order) and p=1/accum=k (micro order).
fn global_loss_curve(per_rank: &[NativeRankResult], accum: usize) -> Vec<f32> {
    let nranks = per_rank.len();
    let steps = per_rank[0].steps.len();
    (0..steps)
        .map(|s| {
            let mut loss = 0.0f32;
            let mut pos = 0usize;
            for mm in 0..accum * nranks {
                let (rank, j) = (mm % nranks, mm / nranks);
                loss += per_rank[rank].steps[s].micro_loss[j];
                pos += per_rank[rank].steps[s].micro_pos[j];
            }
            loss / pos.max(1) as f32
        })
        .collect()
}

/// Run a native end-to-end training session: one executor worker
/// thread per rank over the configured transport, `accum` micro-batch
/// gradients accumulated locally in pooled buffers, one exchange per
/// step through the policy→densify→fused-collective path, Adam on the
/// combined-scaled sum.  See the module docs for the determinism
/// contract the result carries.
pub fn run_native_session(cfg: &NativeTrainConfig) -> anyhow::Result<NativeSessionResult> {
    anyhow::ensure!(cfg.nranks >= 1, "need at least one rank");
    anyhow::ensure!(cfg.steps >= 1, "need at least one step");
    anyhow::ensure!(cfg.accum >= 1, "need at least one micro-batch per step");

    let corpus = Corpus::generate(&cfg.corpus);
    let (train_corpus, test_corpus) = if cfg.eval_pairs > 0 {
        corpus.split(cfg.eval_pairs)
    } else {
        (corpus.clone(), corpus)
    };

    let budget = match cfg.budget_bytes {
        Some(b) => Arc::new(MemoryBudget::limited(b)),
        None => Arc::new(MemoryBudget::unlimited()),
    };
    let transport = cfg.transport.create_with_budget(cfg.nranks, budget)?;

    let t0 = Instant::now();
    let cfg_arc = Arc::new(cfg.clone());
    let corpus_arc = Arc::new(train_corpus);
    let workers: Vec<WorkerFn<NativeRankResult>> = (0..cfg.nranks)
        .map(|rank| {
            let transport = transport.clone();
            let cfg = cfg_arc.clone();
            let corpus = corpus_arc.clone();
            Box::new(move |barrier: &Barrier| native_worker(rank, transport, &cfg, &corpus, barrier))
                as WorkerFn<NativeRankResult>
        })
        .collect();
    let mut per_rank = Vec::with_capacity(cfg.nranks);
    for (rank, joined) in run_worker_threads(workers).into_iter().enumerate() {
        per_rank.push(joined.map_err(|_| anyhow::anyhow!("rank {rank} thread panicked"))?);
    }
    let wall_secs = t0.elapsed().as_secs_f64();

    let loss_curve = global_loss_curve(&per_rank, cfg.accum);
    let bleu = if cfg.eval_pairs > 0 {
        let model = NativeModel::new(cfg.corpus.vocab, cfg.d_model);
        let params = &per_rank[0].params;
        let max_len = cfg.batch.2 * 2;
        let hyps: Vec<Vec<i32>> = test_corpus
            .pairs
            .iter()
            .map(|p| model.greedy_decode(params, &p.src, max_len))
            .collect();
        let refs: Vec<Vec<i32>> = test_corpus.pairs.iter().map(|p| p.tgt.clone()).collect();
        Some(bleu_smoothed(&hyps, &refs))
    } else {
        None
    };

    Ok(NativeSessionResult {
        per_rank,
        loss_curve,
        wall_secs,
        bleu,
        nranks: cfg.nranks,
        accum: cfg.accum,
    })
}

/// Densify a flat params-shaped image of (embedding grad, mixer grad)
/// for tracing.
fn flat_image(model: &NativeModel, emb: &Grad, mix: &[f32]) -> Vec<f32> {
    let (v, d) = (model.vocab, model.d_model);
    let mut flat = vec![0.0f32; model.n_params()];
    match emb {
        Grad::Dense(t) => flat[..v * d].copy_from_slice(&t.data),
        Grad::Sparse(s) => {
            let dense = s.to_dense();
            flat[..v * d].copy_from_slice(&dense.data);
        }
    }
    flat[v * d..].copy_from_slice(mix);
    flat
}

/// One rank's session body (executor worker).
fn native_worker(
    rank: usize,
    transport: Arc<dyn Transport>,
    cfg: &NativeTrainConfig,
    corpus: &Corpus,
    barrier: &Barrier,
) -> NativeRankResult {
    let model = NativeModel::new(corpus.vocab, cfg.d_model);
    let (v, d) = (model.vocab, model.d_model);
    let mut params = model.init_params(cfg.seed);
    let mut opt = Adam::new(model.n_params(), AdamConfig::default());
    let batcher =
        Batcher::new(corpus.clone(), cfg.batch, rank, cfg.nranks, cfg.seed ^ BATCH_SEED_SALT);

    // Accumulators live in a pooled free list charged against the same
    // budget as the transport payloads and the exchange arena.
    let budget =
        transport.memory_budget().unwrap_or_else(|| Arc::new(MemoryBudget::unlimited()));
    let pool = PooledBuffers::new(budget.clone());
    let mut exchange_cfg = cfg.exchange;
    exchange_cfg.average = false; // single combined scale below
    let mut ex = GradExchange::with_budget(transport, rank, exchange_cfg, budget);

    let accum = cfg.accum;
    let nranks = cfg.nranks;
    // ONE combined scale: ÷p then ÷k rounds differently from ÷(p·k),
    // and the accumulation-equivalence contract needs the single form.
    let scale = 1.0 / (nranks * accum) as f32;

    let mut steps_out = Vec::with_capacity(cfg.steps);
    let mut grad_trace = Vec::new();
    for step in 0..cfg.steps {
        barrier.wait(); // executor-aligned step start
        let c0 = Instant::now();

        // mixer accumulator: always dense, pooled, zeroed
        let mut acc_mix = pool.acquire(d * d);
        acc_mix.resize(d * d, 0.0);
        // embedding accumulator: pooled dense buffer (strategies that
        // densify) or concatenated slices (TfDefault keeps gather form)
        let mut acc_emb: Option<Vec<f32>> = None;
        let mut acc_idx: Vec<i32> = Vec::new();
        let mut acc_val: Vec<f32> = Vec::new();

        let mut micro_loss = Vec::with_capacity(accum);
        let mut micro_pos = Vec::with_capacity(accum);
        let mut tokens = 0usize;
        for j in 0..accum {
            // global micro index: ascending-m order IS rank order at
            // accum=1 and micro order at p=1 (module docs)
            let m = step * (accum * nranks) + j * nranks + rank;
            let batch = batcher.batch_at(m);
            tokens += batch.real_tokens();
            let micro = model.forward_backward(&params, &batch);
            micro_loss.push(micro.loss_sum);
            micro_pos.push(micro.n_pos);
            let (tied, mixer) = micro.tied_contributions();
            // local tied accumulation — the paper's strategy axis
            let (tied_acc, _peak) = accumulate(tied, cfg.strategy);
            match tied_acc {
                Grad::Dense(t) => {
                    let acc = acc_emb.get_or_insert_with(|| {
                        let mut b = pool.acquire(v * d);
                        b.resize(v * d, 0.0);
                        b
                    });
                    // fresh zeroed acc += finished micro gradient:
                    // exactly the Naive allreduce's summation sequence
                    for (a, g) in acc.iter_mut().zip(&t.data) {
                        *a += g;
                    }
                }
                Grad::Sparse(s) => {
                    // gather form accumulates by concatenation (exact)
                    acc_idx.extend_from_slice(&s.indices);
                    acc_val.extend_from_slice(&s.values);
                }
            }
            for (a, g) in acc_mix.iter_mut().zip(&mixer.data) {
                *a += g;
            }
        }

        let emb_grad = match acc_emb.take() {
            Some(buf) => Grad::Dense(DenseTensor::from_vec(vec![v, d], buf)),
            None => Grad::Sparse(IndexedSlices::new(
                v,
                d,
                std::mem::take(&mut acc_idx),
                std::mem::take(&mut acc_val),
            )),
        };
        let pre = cfg.trace_grads.then(|| flat_image(&model, &emb_grad, &acc_mix));
        let mix_grad = DenseTensor::from_vec(vec![d, d], acc_mix);
        let mut compute_us = c0.elapsed().as_micros() as u64;

        // one exchange per effective batch
        let e0 = Instant::now();
        let (mut outs, report) = ex.exchange(vec![
            NamedGrad { name: "embedding".into(), grad: emb_grad },
            NamedGrad { name: "mixer".into(), grad: Grad::Dense(mix_grad) },
        ]);
        let exchange_us = e0.elapsed().as_micros() as u64;

        let a0 = Instant::now();
        let mix_out = outs.pop().expect("mixer out");
        let emb_out = outs.pop().expect("embedding out");
        let post = cfg.trace_grads.then(|| {
            let mix_data = match &mix_out.grad {
                Grad::Dense(t) => t.data.clone(),
                Grad::Sparse(_) => unreachable!("mixer is dense"),
            };
            flat_image(&model, &emb_out.grad, &mix_data)
        });
        if let (Some(pre), Some(post)) = (pre, post) {
            grad_trace.push(GradTrace { pre, post });
        }

        opt.begin_step();
        let emb_scaled = match emb_out.grad {
            Grad::Dense(mut t) => {
                t.scale(scale);
                Grad::Dense(t)
            }
            Grad::Sparse(mut s) => {
                s.scale(scale);
                Grad::Sparse(s)
            }
        };
        opt.apply(&mut params, model.emb_offset(), v * d, &emb_scaled, cfg.lr);
        let mut mix_t = match mix_out.grad {
            Grad::Dense(t) => t,
            Grad::Sparse(_) => unreachable!("mixer is dense"),
        };
        mix_t.scale(scale);
        opt.apply_dense(&mut params, model.mixer_offset(), &mix_t.data, cfg.lr);
        compute_us += a0.elapsed().as_micros() as u64;

        // recycle the dense backing buffers (accumulators round-trip
        // through the exchange arena and come back here)
        if let Grad::Dense(t) = emb_scaled {
            pool.release(t.data);
        }
        pool.release(mix_t.data);

        steps_out.push(NativeStepTrace {
            micro_loss,
            micro_pos,
            tokens,
            compute_us,
            exchange_us,
            report,
        });
    }

    NativeRankResult { rank, steps: steps_out, params, pool_stats: pool.stats(), grad_trace }
}

// ---------------------------------------------------------------------------
// Native elastic session: the shrink/rollback protocol on real gradients
// ---------------------------------------------------------------------------

/// Configuration for [`run_native_elastic_session`] — the elastic
/// protocol of [`super::session`] with the native model's gradients
/// (plain SGD, so the closed-form oracle stays replayable).
#[derive(Debug, Clone)]
pub struct NativeElasticConfig {
    /// Initial world size.
    pub nranks: usize,
    /// Optimizer steps survivors must complete.
    pub steps: usize,
    /// Hidden width (vocab comes from `corpus`).
    pub d_model: usize,
    /// Batch shape `(b, ss, st)`.
    pub batch: (usize, usize, usize),
    /// Synthetic corpus.
    pub corpus: CorpusConfig,
    /// SGD learning rate (applied to the mean gradient over members).
    pub lr: f32,
    /// Checkpoint every N committed steps (step-0 baseline always).
    pub checkpoint_every: usize,
    /// Allreduce algorithm.  `Naive` root-sums in dense-rank order —
    /// the order [`native_elastic_oracle`] replays.
    pub algo: AllreduceAlgo,
    /// Wire format for the gradient allreduce.
    pub wire: WireFormat,
    /// Per-receive timeout inside collectives.
    pub recv_timeout: Duration,
    /// Monitor deadline for declaring a silent rank dead.
    pub heartbeat_deadline: Duration,
    /// Fault plan (kill schedules, link faults).
    pub faults: FaultPlan,
    /// Shared checkpoint path.
    pub ckpt_path: PathBuf,
    /// Seed for parameters and batch order.
    pub seed: u64,
    /// Transport kind.
    pub transport: TransportKind,
}

impl NativeElasticConfig {
    /// Small fast defaults for tests.
    pub fn quick(nranks: usize, steps: usize, ckpt_path: PathBuf) -> Self {
        Self {
            nranks,
            steps,
            d_model: 8,
            batch: (2, 8, 8),
            corpus: CorpusConfig { vocab: 32, n_pairs: 128, ..Default::default() },
            lr: 0.1,
            checkpoint_every: 2,
            algo: AllreduceAlgo::Naive,
            wire: WireFormat::F32,
            recv_timeout: Duration::from_millis(150),
            heartbeat_deadline: Duration::from_millis(500),
            faults: FaultPlan::none(),
            ckpt_path,
            seed: 42,
            transport: TransportKind::Shm,
        }
    }

    fn model(&self) -> NativeModel {
        NativeModel::new(self.corpus.vocab, self.d_model)
    }
}

/// The flat params-shaped gradient of one micro-batch: proj, target
/// rows, source rows scattered into the embedding block (fixed order),
/// mixer copied into its block.  Shared verbatim by the workers and
/// the oracle, so both produce identical bits.
fn native_flat_grad(model: &NativeModel, params: &[f32], batch: &Batch) -> Vec<f32> {
    let d = model.d_model;
    let micro = model.forward_backward(params, batch);
    let mut flat = vec![0.0f32; model.n_params()];
    for (i, x) in micro.g_proj.data.iter().enumerate() {
        flat[i] += x;
    }
    for (s, &row) in micro.g_emb_tgt.indices.iter().enumerate() {
        let base = row as usize * d;
        for k in 0..d {
            flat[base + k] += micro.g_emb_tgt.values[s * d + k];
        }
    }
    for (s, &row) in micro.g_emb_src.indices.iter().enumerate() {
        let base = row as usize * d;
        for k in 0..d {
            flat[base + k] += micro.g_emb_src.values[s * d + k];
        }
    }
    flat[model.mixer_offset()..].copy_from_slice(&micro.g_mixer.data);
    flat
}

/// Write the step-0 baseline checkpoint (model-sized) for `cfg`.
pub fn write_native_baseline_checkpoint(cfg: &NativeElasticConfig) -> anyhow::Result<()> {
    let model = cfg.model();
    let zeros = vec![0.0f32; model.n_params()];
    Checkpoint {
        step: 0,
        params: model.init_params(cfg.seed),
        adam_m: zeros.clone(),
        adam_v: zeros,
    }
    .save(&cfg.ckpt_path)?;
    Ok(())
}

/// Run the native elastic session: real model gradients under the
/// checkpoint/shrink recovery protocol.  Survivors finish all steps
/// with bit-identical parameters; a killed rank's run is replayed
/// exactly by [`native_elastic_oracle`].
pub fn run_native_elastic_session(cfg: &NativeElasticConfig) -> anyhow::Result<ElasticReport> {
    anyhow::ensure!(cfg.nranks >= 1, "need at least one rank");
    anyhow::ensure!(cfg.steps >= 1, "need at least one step");
    write_native_baseline_checkpoint(cfg)?;

    let base: Arc<dyn Transport> = cfg.transport.create(cfg.nranks)?;
    let transport: Arc<dyn Transport> = if cfg.faults.has_link_faults() {
        Arc::new(FaultyTransport::new(base, cfg.faults.clone()))
    } else {
        base
    };
    let opts = HealthOpts {
        heartbeat_deadline: cfg.heartbeat_deadline,
        poll: Duration::from_millis(10),
    };
    let corpus = Arc::new(Corpus::generate(&cfg.corpus));
    let cfg_arc = Arc::new(cfg.clone());
    let run = run_elastic(transport, opts, move |rank, t, health| {
        native_elastic_worker(rank, t, &*health, &cfg_arc, &corpus)
    });

    let mut report = ElasticReport {
        survivors: Vec::new(),
        died: Vec::new(),
        evicted: Vec::new(),
        failed: Vec::new(),
    };
    for (rank, exit) in run.exits.into_iter().enumerate() {
        match exit {
            RankExit::Finished(o) => report.survivors.push(o),
            RankExit::Died { cycle } => report.died.push((rank, cycle)),
            RankExit::Evicted => report.evicted.push(rank),
            RankExit::Failed(msg) => report.failed.push((rank, msg)),
        }
    }
    Ok(report)
}

/// Per-rank body of the native elastic loop — the protocol of
/// [`super::session::elastic_worker`] with the synthetic closed-form
/// gradient replaced by [`native_flat_grad`] on the group-sharded
/// batch `step · |members| + dense_rank`.
pub fn native_elastic_worker(
    rank: usize,
    transport: Arc<dyn Transport>,
    coord: &dyn ElasticCoord,
    cfg: &NativeElasticConfig,
    corpus: &Corpus,
) -> RankExit<ElasticOutcome> {
    let model = cfg.model();
    let batcher = Batcher::new(corpus.clone(), cfg.batch, 0, 1, cfg.seed ^ BATCH_SEED_SALT);
    let kill_cycle = cfg.faults.kill_cycle(rank);
    let mut group = Group::world(cfg.nranks);
    let mut params = model.init_params(cfg.seed);
    let mut step: u64 = 0;
    let mut attempt: u64 = 0;
    let mut seq: u64 = 0;
    let mut retries: u64 = 0;
    let mut rollbacks: u64 = 0;
    let steps = cfg.steps as u64;

    while step < steps {
        if kill_cycle == Some(step as usize) {
            return RankExit::Died { cycle: step as usize };
        }
        coord.beat(rank);

        attempt = match coord.sync_start(rank, &group, seq, attempt) {
            Ok(a) => a,
            Err(_) => return RankExit::Evicted,
        };
        seq += 1;
        if attempt >= MAX_ATTEMPTS {
            coord.declare_dead(rank);
            transport.mark_dead(rank);
            return RankExit::Failed(format!(
                "step {step}: retry budget exhausted after {attempt} attempts"
            ));
        }
        let oom = cfg.faults.oom_attempts(rank, step as usize) as u64 > attempt;
        if oom && attempt >= OOM_DEATH_ATTEMPTS {
            coord.declare_dead(rank);
            transport.mark_dead(rank);
            return RankExit::Failed(format!(
                "step {step}: memory budget exhausted after {attempt} degraded retries"
            ));
        }

        let era = group.epoch * 1024 + attempt;
        let sub = SubTransport::new(transport.clone(), group.members.clone(), era);
        let dense = group.dense_rank(rank).expect("member of own group");

        // group-sharded batch: dense rank dr of q members takes micro
        // step·q + dr — the formula the oracle replays
        let batch = batcher.batch_at(step as usize * group.members.len() + dense);
        let mut buf = native_flat_grad(&model, &params, &batch);
        let ok = if oom || coord.group_impaired(&group) {
            false
        } else {
            collectives::try_allreduce_wire_seg(
                &sub,
                dense,
                &mut buf,
                cfg.algo,
                step * TAG_BLOCK,
                cfg.wire,
                degraded_segment(attempt),
                Some(cfg.recv_timeout),
            )
            .is_ok()
        };
        coord.beat(rank);

        let verdict = match coord.commit(rank, &group, seq, ok) {
            Ok(v) => v,
            Err(_) => return RankExit::Evicted,
        };
        seq += 1;

        match verdict {
            Verdict::Commit => {
                let scale = cfg.lr / group.members.len() as f32;
                for (p, g) in params.iter_mut().zip(&buf) {
                    *p -= scale * g;
                }
                step += 1;
                attempt = 0;
                let at_interval =
                    cfg.checkpoint_every > 0 && step % cfg.checkpoint_every as u64 == 0;
                if at_interval || step == steps {
                    if rank == group.leader() {
                        let zeros = vec![0.0f32; model.n_params()];
                        let ck = Checkpoint {
                            step,
                            params: params.clone(),
                            adam_m: zeros.clone(),
                            adam_v: zeros,
                        };
                        if let Err(e) = ck.save(&cfg.ckpt_path) {
                            coord.declare_dead(rank);
                            transport.mark_dead(rank);
                            return RankExit::Failed(format!("checkpoint save: {e}"));
                        }
                    }
                    if coord.sync_point(rank, &group, seq).is_err() {
                        return RankExit::Evicted;
                    }
                    seq += 1;
                }
            }
            Verdict::Retry => {
                attempt += 1;
                retries += 1;
            }
            Verdict::Shrink => {
                group = match coord.regroup(rank, &group) {
                    Ok(g) => g,
                    Err(_) => return RankExit::Evicted,
                };
                seq = 0;
                attempt = 0;
                rollbacks += 1;
                match Checkpoint::load(&cfg.ckpt_path) {
                    Ok(ck) => {
                        step = ck.step;
                        params = ck.params;
                    }
                    Err(e) => {
                        coord.declare_dead(rank);
                        transport.mark_dead(rank);
                        return RankExit::Failed(format!("checkpoint load: {e}"));
                    }
                }
            }
        }
    }

    RankExit::Finished(ElasticOutcome {
        rank,
        params,
        steps_done: step,
        retries,
        rollbacks,
        final_epoch: group.epoch,
        members: group.members,
    })
}

/// Closed-form replay of a native elastic run with one scheduled kill:
/// `kill_rank` dies at the start of step `kill_step`, the survivors
/// shrink and roll back to the last checkpoint
/// `C = ⌊kill_step / checkpoint_every⌋ · checkpoint_every`, so the
/// final parameters are: steps `0..C` with the full group, then steps
/// `C..steps` with the survivors — each step a dense-rank-order
/// (`Naive`) sum of [`native_flat_grad`] over the group-sharded
/// batches, applied at `lr/|members|`.  Pass `kill_step >= steps` (or
/// no kill) to replay a fault-free run.
pub fn native_elastic_oracle(
    cfg: &NativeElasticConfig,
    kill: Option<(usize, usize)>,
) -> Vec<f32> {
    let model = cfg.model();
    let corpus = Corpus::generate(&cfg.corpus);
    let batcher = Batcher::new(corpus, cfg.batch, 0, 1, cfg.seed ^ BATCH_SEED_SALT);
    let mut params = model.init_params(cfg.seed);

    let replay = |params: &mut Vec<f32>, members: &[usize], from: usize, to: usize| {
        let q = members.len();
        let scale = cfg.lr / q as f32;
        for step in from..to {
            // dense-rank-order sum: exactly the Naive allreduce's root
            // accumulation sequence
            let mut sum: Option<Vec<f32>> = None;
            for dense in 0..q {
                let batch = batcher.batch_at(step * q + dense);
                let g = native_flat_grad(&model, params, &batch);
                match &mut sum {
                    None => sum = Some(g),
                    Some(acc) => {
                        for (a, x) in acc.iter_mut().zip(&g) {
                            *a += x;
                        }
                    }
                }
            }
            let sum = sum.expect("at least one member");
            for (p, g) in params.iter_mut().zip(&sum) {
                *p -= scale * g;
            }
        }
    };

    match kill {
        Some((kill_rank, kill_step)) if kill_step < cfg.steps => {
            let c = if cfg.checkpoint_every > 0 {
                (kill_step / cfg.checkpoint_every) * cfg.checkpoint_every
            } else {
                0
            };
            let full: Vec<usize> = (0..cfg.nranks).collect();
            let survivors: Vec<usize> =
                (0..cfg.nranks).filter(|&r| r != kill_rank).collect();
            replay(&mut params, &full, 0, c);
            replay(&mut params, &survivors, c, cfg.steps);
        }
        _ => {
            let full: Vec<usize> = (0..cfg.nranks).collect();
            replay(&mut params, &full, 0, cfg.steps);
        }
    }
    params
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_runs_and_ranks_agree() {
        let cfg = NativeTrainConfig {
            nranks: 2,
            steps: 3,
            d_model: 8,
            corpus: CorpusConfig { vocab: 32, n_pairs: 64, ..Default::default() },
            ..Default::default()
        };
        let r = run_native_session(&cfg).unwrap();
        r.assert_ranks_agree();
        assert_eq!(r.loss_curve.len(), 3);
        assert!(r.loss_curve.iter().all(|l| l.is_finite() && *l > 0.0));
        assert!(r.total_tokens() > 0);
    }

    #[test]
    fn accumulation_pools_recycle() {
        let cfg = NativeTrainConfig {
            nranks: 1,
            steps: 5,
            accum: 2,
            d_model: 8,
            corpus: CorpusConfig { vocab: 32, n_pairs: 64, ..Default::default() },
            transport: TransportKind::Local,
            ..Default::default()
        };
        let r = run_native_session(&cfg).unwrap();
        let s = r.per_rank[0].pool_stats;
        // warm-up allocates; steady state recycles
        assert!(s.allocated > 0);
        assert!(s.recycled > 0, "accumulators must recycle: {s:?}");
    }

    #[test]
    fn accumulator_buffers_charge_the_budget() {
        let cfg = NativeTrainConfig {
            nranks: 1,
            steps: 2,
            d_model: 8,
            corpus: CorpusConfig { vocab: 32, n_pairs: 64, ..Default::default() },
            transport: TransportKind::Local,
            budget_bytes: Some(8 * 1024 * 1024),
            ..Default::default()
        };
        let r = run_native_session(&cfg).unwrap();
        assert!(
            r.per_rank[0].pool_stats.bytes_peak > 0,
            "pooled accumulators must be accounted"
        );
    }

    #[test]
    fn bleu_eval_is_produced() {
        let cfg = NativeTrainConfig {
            nranks: 1,
            steps: 2,
            d_model: 8,
            corpus: CorpusConfig { vocab: 32, n_pairs: 64, ..Default::default() },
            transport: TransportKind::Local,
            eval_pairs: 4,
            ..Default::default()
        };
        let r = run_native_session(&cfg).unwrap();
        let b = r.bleu.expect("bleu requested");
        assert!((0.0..=100.0).contains(&b));
    }

    #[test]
    fn tf_default_strategy_runs_sparse() {
        let cfg = NativeTrainConfig {
            nranks: 2,
            steps: 2,
            d_model: 8,
            strategy: AccumStrategy::TfDefault,
            corpus: CorpusConfig { vocab: 32, n_pairs: 64, ..Default::default() },
            ..Default::default()
        };
        let r = run_native_session(&cfg).unwrap();
        r.assert_ranks_agree();
        // gather path: the exchange must have run allgathers
        assert!(r.per_rank[0].steps[0].report.n_allgather_ops > 0);
    }

    #[test]
    fn native_elastic_fault_free_matches_oracle() {
        let path = std::env::temp_dir()
            .join(format!("densefold_native_elastic_clean_{}.ckpt", std::process::id()));
        let cfg = NativeElasticConfig::quick(2, 3, path.clone());
        let report = run_native_elastic_session(&cfg).unwrap();
        report.assert_survivors_agree(3);
        let want: Vec<u32> =
            native_elastic_oracle(&cfg, None).iter().map(|x| x.to_bits()).collect();
        let got: Vec<u32> =
            report.survivors[0].params.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want, "fault-free run must match the closed-form replay");
        let _ = std::fs::remove_file(path);
    }
}
