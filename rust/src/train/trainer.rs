//! The per-rank trainer: PJRT step execution → local accumulation of
//! the tied-embedding gradient under the chosen strategy → coordinated
//! exchange → Adam update.
//!
//! Strategy → artifact mapping (the heart of the reproduction):
//!
//! | strategy        | artifact      | tied-embedding local accumulation      | exchange      |
//! |-----------------|---------------|----------------------------------------|---------------|
//! | `TfDefault`     | `step_sparse` | Algorithm 1 → IndexedSlices concat     | **allgather** |
//! | `SparseAsDense` | `step_dense`  | Pallas densify **in-graph** (Listing 1)| allreduce     |
//! | `AnyDense`      | `step_sparse` | Algorithm 2 → Rust scatter-add         | allreduce     |

use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{ExchangeConfig, ExchangeReport, GradExchange, NamedGrad};
use crate::data::{Batch, Batcher, Corpus};
use crate::model::{GradKind, IndexSource, ParamRegistry};
use crate::runtime::{EngineHandle, HostTensor, Manifest, Preset};
use crate::tensor::{accumulate, AccumStrategy, DenseTensor, Grad, IndexedSlices};
use crate::transport::Transport;
use crate::train::{Adam, NoamSchedule};
use crate::train::optimizer::AdamConfig;

/// Trainer configuration shared by all ranks.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub preset: String,
    pub strategy: AccumStrategy,
    pub exchange: ExchangeConfig,
    pub warmup_steps: u64,
    pub lr_scale: f32,
    pub seed: u64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        Self {
            preset: "tiny".into(),
            strategy: AccumStrategy::SparseAsDense,
            exchange: ExchangeConfig::default(),
            warmup_steps: 200,
            lr_scale: 1.0,
            seed: 17,
        }
    }
}

/// Per-step measurements.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: u64,
    pub loss: f32,
    pub tokens: usize,
    pub compute_us: u64,
    pub exchange: ExchangeReport,
    pub apply_us: u64,
    pub lr: f32,
}

/// One rank's trainer.
pub struct Trainer {
    pub rank: usize,
    pub nranks: usize,
    engine: EngineHandle,
    exe: String,
    fwd_exe: Option<String>,
    registry: ParamRegistry,
    pub params: Vec<f32>,
    opt: Adam,
    schedule: NoamSchedule,
    exchange: GradExchange,
    batcher: Batcher,
    grad_outputs: Vec<(String, Vec<usize>)>,
    strategy: AccumStrategy,
    batch_shape: (usize, usize, usize),
    step: u64,
}

/// Artifact registration key for a preset + kind.
pub fn exe_name(preset: &str, kind: &str) -> String {
    format!("{preset}:{kind}")
}

/// Load the step (and forward) artifacts for a preset into the engine.
/// Idempotent per engine; call once before spawning rank threads.
pub fn load_artifacts(
    engine: &EngineHandle,
    manifest: &Manifest,
    preset_name: &str,
    strategy: AccumStrategy,
    with_forward: bool,
) -> anyhow::Result<()> {
    let preset = manifest.preset(preset_name)?;
    let kind = step_kind(strategy);
    let file = preset
        .artifacts
        .get(kind)
        .ok_or_else(|| anyhow::anyhow!("no {kind} artifact"))?;
    engine.load(&exe_name(preset_name, kind), manifest.artifact_path(file))?;
    if with_forward {
        let fwd = preset
            .artifacts
            .get("forward")
            .ok_or_else(|| anyhow::anyhow!("no forward artifact"))?;
        engine.load(&exe_name(preset_name, "forward"), manifest.artifact_path(fwd))?;
    }
    Ok(())
}

fn step_kind(strategy: AccumStrategy) -> &'static str {
    match strategy {
        AccumStrategy::SparseAsDense => "step_dense",
        AccumStrategy::TfDefault | AccumStrategy::AnyDense => "step_sparse",
    }
}

impl Trainer {
    /// Build a trainer for `rank`. The artifacts must already be loaded
    /// via [`load_artifacts`].
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: &TrainerConfig,
        manifest: &Manifest,
        preset: &Preset,
        engine: EngineHandle,
        transport: Arc<dyn Transport>,
        rank: usize,
        corpus: Corpus,
        params: Vec<f32>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(params.len() == preset.n_params, "bad params length");
        let nranks = transport.nranks();
        let registry = ParamRegistry::from_preset(preset);
        let batch_shape = (preset.batch.b, preset.batch.ss, preset.batch.st);
        let batcher = Batcher::new(corpus, batch_shape, rank, nranks, cfg.seed);
        let dense = matches!(cfg.strategy, AccumStrategy::SparseAsDense);
        let grad_outputs = preset.grad_outputs(dense);
        let _ = manifest; // path resolution happens in load_artifacts
        Ok(Self {
            rank,
            nranks,
            engine,
            exe: exe_name(&cfg.preset, step_kind(cfg.strategy)),
            fwd_exe: Some(exe_name(&cfg.preset, "forward")),
            registry,
            params,
            opt: Adam::new(preset.n_params, AdamConfig::default()),
            schedule: NoamSchedule::new(preset.config.d_model, cfg.warmup_steps, cfg.lr_scale),
            exchange: GradExchange::new(transport, rank, cfg.exchange),
            batcher,
            grad_outputs,
            strategy: cfg.strategy,
            batch_shape,
            step: 0,
        })
    }

    pub fn enable_timeline(&mut self) {
        self.exchange.enable_timeline();
    }

    pub fn timeline(&self) -> &crate::coordinator::timeline::Timeline {
        &self.exchange.timeline
    }

    /// Execute one data-parallel training step.
    pub fn train_step(&mut self) -> anyhow::Result<StepStats> {
        self.step += 1;
        let batch = self.batcher.next_batch();

        // ---- compute (PJRT) ----
        let t0 = Instant::now();
        let outputs = self.engine.execute(&self.exe, self.build_inputs(&batch))?;
        let compute_us = t0.elapsed().as_micros() as u64;
        let loss = outputs[0].scalar_f32();

        // ---- local accumulation under the strategy ----
        let mut outputs = outputs;
        let grad_outputs: Vec<HostTensor> = outputs.drain(1..).collect();
        let grads = self.collect_grads(grad_outputs, &batch);

        // ---- coordinated exchange ----
        let (reduced, report) = self.exchange.exchange(grads);

        // ---- optimizer ----
        let t1 = Instant::now();
        let lr = self.schedule.lr(self.step);
        self.opt.begin_step();
        for ng in &reduced {
            let spec = self
                .registry
                .spec(&ng.name)
                .unwrap_or_else(|| panic!("grad for unknown param {}", ng.name));
            let (offset, numel) = (spec.offset, spec.numel);
            self.opt.apply(&mut self.params, offset, numel, &ng.grad, lr);
        }
        let apply_us = t1.elapsed().as_micros() as u64;

        Ok(StepStats {
            step: self.step,
            loss,
            tokens: batch.real_tokens(),
            compute_us,
            exchange: report,
            apply_us,
            lr,
        })
    }

    /// Flatten params + batch into the HLO input order.
    fn build_inputs(&self, batch: &Batch) -> Vec<HostTensor> {
        let mut inputs = Vec::with_capacity(self.registry.params.len() + 3);
        for p in &self.registry.params {
            inputs.push(HostTensor::f32(
                p.shape.clone(),
                self.params[p.offset..p.offset + p.numel].to_vec(),
            ));
        }
        let (b, ss, st) = self.batch_shape;
        inputs.push(HostTensor::i32(vec![b, ss], batch.src.clone()));
        inputs.push(HostTensor::i32(vec![b, st], batch.tgt_in.clone()));
        inputs.push(HostTensor::i32(vec![b, st], batch.tgt_out.clone()));
        inputs
    }

    /// Map step outputs to named gradients, locally accumulating the
    /// tied-embedding contributions per the strategy table above.
    fn collect_grads(&self, outputs: Vec<HostTensor>, batch: &Batch) -> Vec<NamedGrad> {
        let vocab = self.registry.vocab;
        let d = self.registry.d_model;
        let mut tied: Vec<Grad> = Vec::new();
        let mut named: Vec<NamedGrad> = Vec::new();
        let mut tied_pos: Option<usize> = None;

        for ((name, _shape), out) in self.grad_outputs.iter().zip(outputs) {
            match self.registry.grad_kind(name) {
                GradKind::Dense { param } => {
                    // move the buffer straight out of the engine reply —
                    // no copy on the per-step hot path (see §Perf)
                    let (shape, data) = match out {
                        HostTensor::F32 { shape, data } => (shape, data),
                        _ => panic!("grad must be f32"),
                    };
                    named.push(NamedGrad {
                        name: param,
                        grad: Grad::Dense(DenseTensor::from_vec(shape, data)),
                    });
                }
                GradKind::SparseRows { param, index_source } => {
                    let values = out.into_f32();
                    let indices: Vec<i32> = match index_source {
                        IndexSource::Src => batch.src.clone(),
                        IndexSource::TgtIn => batch.tgt_in.clone(),
                    };
                    assert_eq!(values.len(), indices.len() * d);
                    tied.push(Grad::Sparse(IndexedSlices::new(vocab, d, indices, values)));
                    if tied_pos.is_none() {
                        tied_pos = Some(named.len());
                        named.push(NamedGrad {
                            name: param,
                            grad: Grad::Dense(DenseTensor::zeros(vec![0])), // placeholder
                        });
                    }
                }
                GradKind::TiedDense { param } => {
                    let data = out.into_f32();
                    tied.push(Grad::Dense(DenseTensor::from_vec(vec![vocab, d], data)));
                    if tied_pos.is_none() {
                        tied_pos = Some(named.len());
                        named.push(NamedGrad {
                            name: param,
                            grad: Grad::Dense(DenseTensor::zeros(vec![0])),
                        });
                    }
                }
            }
        }
        if let Some(pos) = tied_pos {
            // local accumulation — Algorithm 1 / Listing 1 / Algorithm 2
            let (grad, _peak) = accumulate(tied, self.strategy);
            named[pos].grad = grad;
        }
        named
    }

    /// Greedy decode: repeated full-forward argmax (inference path for
    /// BLEU evaluation).  `srcs` are content-token sequences; returns
    /// the decoded content tokens (EOS-terminated internally).
    pub fn greedy_decode(&self, srcs: &[Vec<i32>]) -> anyhow::Result<Vec<Vec<i32>>> {
        use crate::data::corpus::{BOS_ID, EOS_ID, PAD_ID};
        let fwd = self.fwd_exe.as_ref().expect("forward artifact not loaded");
        let (b, ss, st) = self.batch_shape;
        let vocab = self.registry.vocab;
        let mut hyps = Vec::with_capacity(srcs.len());
        for chunk in srcs.chunks(b) {
            let mut src = vec![PAD_ID; b * ss];
            for (row, s) in chunk.iter().enumerate() {
                let n = s.len().min(ss - 1);
                src[row * ss..row * ss + n].copy_from_slice(&s[..n]);
                src[row * ss + n] = EOS_ID;
            }
            let mut tgt_in = vec![PAD_ID; b * st];
            for row in 0..b {
                tgt_in[row * st] = BOS_ID;
            }
            let mut done = vec![false; b];
            let mut out_tokens: Vec<Vec<i32>> = vec![Vec::new(); b];
            for pos in 0..st - 1 {
                let mut inputs = Vec::with_capacity(self.registry.params.len() + 2);
                for p in &self.registry.params {
                    inputs.push(HostTensor::f32(
                        p.shape.clone(),
                        self.params[p.offset..p.offset + p.numel].to_vec(),
                    ));
                }
                inputs.push(HostTensor::i32(vec![b, ss], src.clone()));
                inputs.push(HostTensor::i32(vec![b, st], tgt_in.clone()));
                let outputs = self.engine.execute(fwd, inputs)?;
                let logits = outputs[0].clone().into_f32(); // [b, st, vocab]
                for row in 0..chunk.len() {
                    if done[row] {
                        continue;
                    }
                    let base = (row * st + pos) * vocab;
                    let mut best = 0usize;
                    let mut best_v = f32::NEG_INFINITY;
                    // never emit PAD/BOS
                    for t in 2..vocab {
                        let v = logits[base + t];
                        if v > best_v {
                            best_v = v;
                            best = t;
                        }
                    }
                    if best as i32 == EOS_ID {
                        done[row] = true;
                    } else {
                        out_tokens[row].push(best as i32);
                        tgt_in[row * st + pos + 1] = best as i32;
                    }
                }
                if done.iter().take(chunk.len()).all(|&d| d) {
                    break;
                }
            }
            hyps.extend(out_tokens.into_iter().take(chunk.len()));
        }
        Ok(hyps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_kind_mapping() {
        assert_eq!(step_kind(AccumStrategy::TfDefault), "step_sparse");
        assert_eq!(step_kind(AccumStrategy::SparseAsDense), "step_dense");
        assert_eq!(step_kind(AccumStrategy::AnyDense), "step_sparse");
    }

    #[test]
    fn exe_name_format() {
        assert_eq!(exe_name("tiny", "step_dense"), "tiny:step_dense");
    }
}
