//! Densification policy — *when* to turn an assumed-sparse gradient
//! into a dense one.
//!
//! The paper hard-wires its answer (densify the transformer's
//! embedding gradients) via the per-run
//! [`crate::tensor::AccumStrategy`].  This module turns that one-time
//! insight into a measured, self-tuning decision: the coordinator asks
//! a [`DensifyPolicy`] each cycle, per tensor, whether the sparse
//! submission should ride the dense allreduce (densify up front) or
//! the TF-semantics allgather.  Adaptive policies consult the
//! EWMA-smoothed occupancy history
//! ([`crate::tensor::occupancy::OccupancyTracker`]); the cost-model
//! policy prices both collectives with the α–β terms of
//! [`crate::collectives::cost`], mirroring Mesh-TensorFlow's
//! per-tensor layout reasoning.
//!
//! ## Lockstep determinism
//!
//! Every rank runs its own [`PolicyEngine`], and all ranks **must**
//! reach the same decision every cycle or the readiness negotiation
//! panics (the paper's mixed-representation hazard).  The engine
//! guarantees this by construction: decisions are a pure function of
//! (policy, per-tensor history), and the history is only ever fed
//! *exchange outputs*, which are identical on all ranks — the
//! allgather concatenates in rank order, and the ring-family allreduce
//! is bit-identical across ranks (even under a lossy wire format, via
//! owner-chunk quantization).  Cold start is deterministic too: no
//! history means [`Decision::Gather`], the TF-faithful default.

use crate::collectives::cost::{
    memory_pressure_factor, ring_allgather_time, ring_pipelined_allreduce_time_wire, LinkModel,
};
use crate::collectives::ring::DEFAULT_SEGMENT_ELEMS;
use crate::tensor::occupancy::OccupancyTracker;
use crate::tensor::Grad;
use crate::transport::{Pressure, WireFormat};

/// EWMA smoothing factor for the occupancy history: heavy enough that
/// one odd batch cannot flip the representation, light enough to
/// converge within a few cycles.
const EWMA_ALPHA: f64 = 0.4;

/// What the coordinator should do with a sparse submission this cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Densify up front and ride the fused dense allreduce.
    Dense,
    /// Keep IndexedSlices and allgather (TF concatenation semantics).
    Gather,
}

/// Per-tensor densification policy, consulted by
/// [`crate::coordinator::GradExchange`] every exchange cycle.
///
/// ```
/// use densefold::coordinator::policy::{Decision, DensifyPolicy, PolicyEngine};
/// use densefold::tensor::{Grad, IndexedSlices};
/// use densefold::transport::WireFormat;
///
/// let mut engine = PolicyEngine::new(DensifyPolicy::Adaptive { dense_above: 0.5 });
/// // cold start: no history yet — stay on the TF gather path
/// assert_eq!(engine.decide(7, 8, 4, 2, WireFormat::F32), Decision::Gather);
///
/// // the exchange output shows every row of the variable carries
/// // gradient: the "sparse" tensor is actually dense
/// let gathered = IndexedSlices::new(8, 4, (0..8i32).collect(), vec![1.0; 32]);
/// engine.observe(7, &Grad::Sparse(gathered), 2);
/// assert_eq!(engine.decide(7, 8, 4, 2, WireFormat::F32), Decision::Dense);
///
/// // policies parse from the CLI surface
/// assert_eq!(DensifyPolicy::parse("adaptive:0.25"),
///            Some(DensifyPolicy::Adaptive { dense_above: 0.25 }));
/// assert_eq!(DensifyPolicy::parse("cost-model"), Some(DensifyPolicy::CostModel));
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DensifyPolicy {
    /// Respect the submitted representation: sparse stays sparse
    /// (TF/Horovod default dispatch; the engine's zero-overhead
    /// default).
    AlwaysGather,
    /// Densify every sparse submission (the paper's fix, Listing 1,
    /// applied at the coordinator instead of the accumulation layer).
    AlwaysDense,
    /// Densify when the EWMA-smoothed row occupancy of the *exchanged*
    /// gradient is at least `dense_above` (in `[0, 1]`).
    Adaptive {
        /// Occupancy threshold at/above which the tensor goes dense.
        dense_above: f64,
    },
    /// Price both collectives with the α–β cost model each cycle
    /// (dense pipelined-ring allreduce of `nrows·row_width` f32 under
    /// the configured wire format vs. ring allgather of the observed
    /// per-rank slice volume) and pick the cheaper.
    CostModel,
}

impl DensifyPolicy {
    /// Parse a CLI/config string: `always-gather`/`gather`,
    /// `always-dense`/`dense`, `adaptive` (threshold 0.5),
    /// `adaptive:<threshold>`, `cost-model`/`cost`.
    pub fn parse(s: &str) -> Option<Self> {
        if let Some(t) = s.strip_prefix("adaptive:") {
            let dense_above: f64 = t.parse().ok()?;
            if !(0.0..=1.0).contains(&dense_above) {
                return None;
            }
            return Some(Self::Adaptive { dense_above });
        }
        match s {
            "always-gather" | "gather" => Some(Self::AlwaysGather),
            "always-dense" | "dense" => Some(Self::AlwaysDense),
            "adaptive" => Some(Self::Adaptive { dense_above: 0.5 }),
            "cost-model" | "cost" => Some(Self::CostModel),
            _ => None,
        }
    }

    /// Stable name for tables and logs.
    pub fn name(&self) -> &'static str {
        match self {
            Self::AlwaysGather => "always-gather",
            Self::AlwaysDense => "always-dense",
            Self::Adaptive { .. } => "adaptive",
            Self::CostModel => "cost-model",
        }
    }

    /// Whether this policy needs the occupancy-observation pass over
    /// exchange outputs (the fixed policies decide without history).
    pub fn is_adaptive(&self) -> bool {
        matches!(self, Self::Adaptive { .. } | Self::CostModel)
    }
}

/// Per-rank policy engine: the policy plus the per-tensor occupancy
/// history it decides from.  See the module docs for the lockstep
/// determinism argument.
#[derive(Debug)]
pub struct PolicyEngine {
    policy: DensifyPolicy,
    tracker: OccupancyTracker,
    /// Link model pricing the cost-model policy (the in-process
    /// transport is shared-memory-class).
    link: LinkModel,
}

impl PolicyEngine {
    /// Engine for `policy` with the default EWMA smoothing and a
    /// shared-memory link model.
    pub fn new(policy: DensifyPolicy) -> Self {
        Self {
            policy,
            tracker: OccupancyTracker::new(EWMA_ALPHA),
            link: LinkModel::shared_memory(),
        }
    }

    /// Engine pricing the cost-model policy against a specific link.
    pub fn with_link(policy: DensifyPolicy, link: LinkModel) -> Self {
        Self { policy, tracker: OccupancyTracker::new(EWMA_ALPHA), link }
    }

    /// The configured policy.
    pub fn policy(&self) -> DensifyPolicy {
        self.policy
    }

    /// Decide the representation for a sparse submission to variable
    /// `id` of shape `[nrows, row_width]`, exchanged across `p` ranks
    /// with dense traffic encoded as `wire`.  Pure in the engine state.
    pub fn decide(
        &self,
        id: u64,
        nrows: usize,
        row_width: usize,
        p: usize,
        wire: WireFormat,
    ) -> Decision {
        self.decide_under(id, nrows, row_width, p, wire, Pressure::Ok)
    }

    /// [`PolicyEngine::decide`] at a given memory-pressure level.
    ///
    /// Pressure biases the *adaptive* policies toward the dense path,
    /// whose working set is fixed (`nrows·row_width` plus one pipeline
    /// segment) regardless of p: the cost model multiplies the gather
    /// plan's time by [`memory_pressure_factor`] (pricing its
    /// p-scaling resident buffers), and the adaptive threshold drops
    /// by the same factor.  The fixed policies are a user's explicit
    /// representation choice and are never overridden.  **Lockstep:**
    /// `level` must be identical on every rank — the coordinator
    /// broadcasts rank 0's reading with the plan, exactly like the
    /// segment size; feeding local readings diverges the plans.
    pub fn decide_under(
        &self,
        id: u64,
        nrows: usize,
        row_width: usize,
        p: usize,
        wire: WireFormat,
        level: Pressure,
    ) -> Decision {
        let pressured = level != Pressure::Ok;
        match self.policy {
            DensifyPolicy::AlwaysGather => Decision::Gather,
            DensifyPolicy::AlwaysDense => Decision::Dense,
            DensifyPolicy::Adaptive { dense_above } => {
                let threshold = dense_above / memory_pressure_factor(level);
                match self.tracker.stats(id) {
                    Some(s) if s.occupancy >= threshold => Decision::Dense,
                    // no history yet: under pressure prefer the
                    // fixed-size dense plan over an unbounded gather
                    None if pressured => Decision::Dense,
                    _ => Decision::Gather,
                }
            }
            DensifyPolicy::CostModel => {
                let Some(s) = self.tracker.stats(id) else {
                    // deterministic cold start: TF-faithful gather,
                    // unless memory is already scarce
                    return if pressured { Decision::Dense } else { Decision::Gather };
                };
                let dense_bytes = (nrows * row_width * 4) as f64;
                let seg_bytes = (DEFAULT_SEGMENT_ELEMS * 4) as f64;
                let reduce_t = ring_pipelined_allreduce_time_wire(
                    &self.link,
                    p as u64,
                    dense_bytes,
                    seg_bytes,
                    wire,
                );
                // the gather ships f32 values + i32 indices, uncompressed
                let per_rank = s.rows_per_rank * (row_width as f64 * 4.0 + 4.0);
                let gather_t = ring_allgather_time(&self.link, p as u64, per_rank)
                    * memory_pressure_factor(level);
                if reduce_t <= gather_t {
                    Decision::Dense
                } else {
                    Decision::Gather
                }
            }
        }
    }

    /// Feed one exchange *output* back into the history.  Call with
    /// the accumulated gradient every rank received — identical bits
    /// on all ranks — never with per-rank inputs.
    pub fn observe(&mut self, id: u64, out: &Grad, p: usize) {
        match out {
            Grad::Sparse(s) => self.tracker.observe_gathered(id, s, p),
            Grad::Dense(t) => self.tracker.observe_dense(id, t),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{DenseTensor, IndexedSlices};

    fn gathered(nrows: usize, idx: Vec<i32>) -> Grad {
        let n = idx.len();
        Grad::Sparse(IndexedSlices::new(nrows, 2, idx, vec![1.0; n * 2]))
    }

    #[test]
    fn fixed_policies_ignore_history() {
        let mut dense = PolicyEngine::new(DensifyPolicy::AlwaysDense);
        let mut gather = PolicyEngine::new(DensifyPolicy::AlwaysGather);
        for e in [&mut dense, &mut gather] {
            e.observe(1, &gathered(8, vec![0, 1, 2, 3, 4, 5, 6, 7]), 2);
        }
        assert_eq!(dense.decide(1, 8, 2, 2, WireFormat::F32), Decision::Dense);
        assert_eq!(gather.decide(1, 8, 2, 2, WireFormat::F32), Decision::Gather);
        assert!(!DensifyPolicy::AlwaysDense.is_adaptive());
        assert!(DensifyPolicy::CostModel.is_adaptive());
    }

    #[test]
    fn adaptive_threshold_flips_on_observed_occupancy() {
        let mut e = PolicyEngine::new(DensifyPolicy::Adaptive { dense_above: 0.5 });
        assert_eq!(e.decide(1, 8, 2, 2, WireFormat::F32), Decision::Gather, "cold start");
        e.observe(1, &gathered(8, (0..8).collect()), 2); // occupancy 1.0
        assert_eq!(e.decide(1, 8, 2, 2, WireFormat::F32), Decision::Dense);
        // a genuinely sparse tensor under the same engine stays gather
        e.observe(2, &gathered(100, vec![3, 3]), 2); // occupancy 0.01
        assert_eq!(e.decide(2, 100, 2, 2, WireFormat::F32), Decision::Gather);
    }

    #[test]
    fn adaptive_is_smoothed_not_flappy() {
        // one dense-looking batch in a sparse stream must not flip the
        // decision: EWMA needs sustained evidence
        let mut e = PolicyEngine::new(DensifyPolicy::Adaptive { dense_above: 0.5 });
        for _ in 0..5 {
            e.observe(1, &gathered(100, vec![1, 2]), 2); // occ 0.02
        }
        e.observe(1, &gathered(100, (0..100).collect()), 2); // occ 1.0 once
        // EWMA(0.4): 0.02 + 0.4*(1.0-0.02) ≈ 0.41 < 0.5
        assert_eq!(e.decide(1, 100, 2, 2, WireFormat::F32), Decision::Gather);
        e.observe(1, &gathered(100, (0..100).collect()), 2); // sustained
        assert_eq!(e.decide(1, 100, 2, 2, WireFormat::F32), Decision::Dense);
    }

    #[test]
    fn adaptive_reads_dense_outputs_too() {
        // once densified, occupancy is observed on the reduced tensor,
        // so a stream that turns sparse flips back
        let mut e = PolicyEngine::new(DensifyPolicy::Adaptive { dense_above: 0.5 });
        let mut hot = DenseTensor::zeros(vec![4, 2]);
        hot.data.iter_mut().for_each(|x| *x = 1.0);
        e.observe(1, &Grad::Dense(hot), 2);
        assert_eq!(e.decide(1, 4, 2, 2, WireFormat::F32), Decision::Dense);
        let cold = DenseTensor::zeros(vec![4, 2]); // all rows empty
        for _ in 0..4 {
            e.observe(1, &Grad::Dense(cold.clone()), 2);
        }
        assert_eq!(e.decide(1, 4, 2, 2, WireFormat::F32), Decision::Gather);
    }

    #[test]
    fn cost_model_prefers_dense_at_high_occupancy_scale() {
        // V=2048, D=16, p=4: dense 128 KB allreduce beats gathering
        // 4×2048 slice rows (see the sizing argument in the PR notes)
        let mut e = PolicyEngine::new(DensifyPolicy::CostModel);
        assert_eq!(e.decide(1, 2048, 16, 4, WireFormat::F32), Decision::Gather, "cold");
        e.observe(1, &gathered_wide(2048, 16, (0..2048).collect()), 1);
        assert_eq!(e.decide(1, 2048, 16, 4, WireFormat::F32), Decision::Dense);
    }

    #[test]
    fn cost_model_flips_back_when_stream_turns_sparse() {
        // no one-way ratchet: dense observations keep feeding the
        // rows-per-rank estimate, so a stream that empties out flips
        // back to gather
        let mut e = PolicyEngine::new(DensifyPolicy::CostModel);
        e.observe(1, &gathered_wide(2048, 16, (0..2048).collect()), 1);
        assert_eq!(e.decide(1, 2048, 16, 4, WireFormat::F32), Decision::Dense);
        let mut thin = DenseTensor::zeros(vec![2048, 16]);
        thin.data[0] = 1.0; // one occupied row
        for _ in 0..8 {
            e.observe(1, &Grad::Dense(thin.clone()), 4);
        }
        assert_eq!(e.decide(1, 2048, 16, 4, WireFormat::F32), Decision::Gather);
    }

    #[test]
    fn cost_model_prefers_gather_when_truly_sparse() {
        let mut e = PolicyEngine::new(DensifyPolicy::CostModel);
        e.observe(1, &gathered_wide(2048, 16, vec![5, 9]), 2); // 1 row/rank
        assert_eq!(e.decide(1, 2048, 16, 4, WireFormat::F32), Decision::Gather);
    }

    fn gathered_wide(nrows: usize, d: usize, idx: Vec<i32>) -> Grad {
        let n = idx.len();
        Grad::Sparse(IndexedSlices::new(nrows, d, idx, vec![1.0; n * d]))
    }

    #[test]
    fn pressure_biases_adaptive_policies_toward_dense() {
        // borderline-sparse stream: gather at Ok, dense once pressured
        let mut e = PolicyEngine::new(DensifyPolicy::Adaptive { dense_above: 0.5 });
        for _ in 0..6 {
            e.observe(1, &gathered(100, (0..20).collect()), 2); // occ 0.2
        }
        assert_eq!(e.decide(1, 100, 2, 2, WireFormat::F32), Decision::Gather);
        assert_eq!(
            e.decide_under(1, 100, 2, 2, WireFormat::F32, Pressure::Soft),
            Decision::Dense,
            "0.2 >= 0.5/4"
        );

        // cost model: a gather that wins on time loses once its
        // p-scaling buffers are priced at Soft pressure
        let mut c = PolicyEngine::new(DensifyPolicy::CostModel);
        c.observe(1, &gathered_wide(2048, 16, (0..280).collect()), 1);
        assert_eq!(c.decide(1, 2048, 16, 4, WireFormat::F32), Decision::Gather);
        assert_eq!(
            c.decide_under(1, 2048, 16, 4, WireFormat::F32, Pressure::Soft),
            Decision::Dense
        );

        // cold start under pressure prefers the bounded dense plan
        let cold = PolicyEngine::new(DensifyPolicy::CostModel);
        assert_eq!(
            cold.decide_under(9, 64, 4, 4, WireFormat::F32, Pressure::Hard),
            Decision::Dense
        );
        // explicit fixed policies are never overridden
        let g = PolicyEngine::new(DensifyPolicy::AlwaysGather);
        assert_eq!(
            g.decide_under(9, 64, 4, 4, WireFormat::F32, Pressure::Hard),
            Decision::Gather
        );
    }

    #[test]
    fn parse_roundtrip_and_bounds() {
        for p in [
            DensifyPolicy::AlwaysGather,
            DensifyPolicy::AlwaysDense,
            DensifyPolicy::Adaptive { dense_above: 0.5 },
            DensifyPolicy::CostModel,
        ] {
            assert_eq!(DensifyPolicy::parse(p.name()).map(|q| q.name()), Some(p.name()));
        }
        assert_eq!(
            DensifyPolicy::parse("adaptive:0.75"),
            Some(DensifyPolicy::Adaptive { dense_above: 0.75 })
        );
        assert_eq!(DensifyPolicy::parse("adaptive:1.5"), None);
        assert_eq!(DensifyPolicy::parse("nope"), None);
    }
}
