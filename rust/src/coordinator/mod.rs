//! The Horovod-class gradient-exchange coordinator — the paper's L3
//! system contribution.
//!
//! Protocol per exchange cycle (identical in shape to Horovod's
//! controller):
//!
//! 1. **Readiness report** — every rank sends rank 0 the ordered list
//!    of gradients it has ready: `(name-id, representation, bytes)`.
//! 2. **Negotiation** — rank 0 verifies all ranks agree (same tensors,
//!    same order, same representation — divergence is a hard error,
//!    exactly the class of bug that produced the paper's segfaults),
//!    then builds the execution [`plan::Plan`]: dense tensors packed
//!    into fusion groups (`fusion_threshold`), sparse tensors as
//!    singleton allgathers.
//! 3. **Plan broadcast** — over the same transport (control plane =
//!    data plane, like MPI).
//! 4. **Execution** — every rank walks the plan: pack → allreduce →
//!    unpack for dense groups; `allgather_indexed_slices` (TF
//!    concatenation semantics) for sparse tensors.  All phases are
//!    recorded on the [`timeline::Timeline`].
//!
//! The *representation* of each gradient is decided upstream by the
//! [`crate::tensor::AccumStrategy`] (which HLO artifact ran and what
//! local accumulation did) — the coordinator, like Horovod, dispatches
//! purely on what it is handed. That faithful division is what lets
//! one binary reproduce both Fig. 3a (gather) and Fig. 3b (reduce).

pub mod cache;
pub mod fusion;
pub mod plan;
pub mod policy;
pub mod timeline;

use std::sync::{Arc, Mutex};

use crate::collectives::{self, ring, tree, AllreduceAlgo, ALGO_PHASE_TAGS, TAG_BLOCK};
use crate::tensor::{DenseTensor, Grad, IndexedSlices};
use crate::transport::budget::DEFAULT_CHARGE_WAIT;
use crate::transport::pool::{acquire_from, release_to, PoolCounters};
use crate::transport::{MemoryBudget, Payload, PoolStats, Pressure, Transport, WireFormat};
use cache::ResponseCache;
use fusion::FusionArena;
use plan::{build_plan, name_id, CollectiveOp, Plan, TensorReport};
use policy::{Decision, DensifyPolicy, PolicyEngine};
use timeline::{Phase, Timeline};

/// Tag planes inside one cycle's TAG_BLOCK.
const CTL_READY: u64 = 0;
const CTL_PLAN: u64 = 1;
const DATA_BASE: u64 = 16;
/// Tag space per plan entry (ring/tree use << this many tags).
const ENTRY_TAGS: u64 = 1 << 12;
/// Plan entries per cycle the tag layout can host.
const MAX_PLAN_ENTRIES: u64 = (TAG_BLOCK - DATA_BASE) / ENTRY_TAGS;

// One allreduce invocation (both phases of a multi-phase algorithm)
// must fit inside a plan entry's tag sub-block, and at least one
// sub-block must fit inside a cycle's TAG_BLOCK.
const _: () = assert!(2 * ALGO_PHASE_TAGS <= ENTRY_TAGS);
const _: () = assert!(DATA_BASE + ENTRY_TAGS <= TAG_BLOCK);
const _: () = assert!(MAX_PLAN_ENTRIES >= 256, "tag layout too tight for real plans");

/// A named gradient as submitted by the trainer.
#[derive(Debug, Clone)]
pub struct NamedGrad {
    pub name: String,
    pub grad: Grad,
}

/// Configuration of the exchange engine.
#[derive(Debug, Clone, Copy)]
pub struct ExchangeConfig {
    /// Allreduce algorithm for the fused dense path.
    pub algo: AllreduceAlgo,
    /// Fusion threshold in bytes (HOROVOD_FUSION_THRESHOLD; the paper
    /// ran with 128 MB).
    pub fusion_threshold: u64,
    /// Divide reduced gradients by p (data-parallel averaging).
    pub average: bool,
    /// Cache negotiated plans keyed by the readiness fingerprint
    /// (Horovod's response cache).  Steady-state cycles then exchange
    /// one fingerprint instead of the full readiness report + plan.
    pub cache_plans: bool,
    /// Densification policy for sparse submissions — consulted per
    /// tensor per cycle (see [`policy::DensifyPolicy`]).  The default
    /// `AlwaysGather` reproduces the faithful Horovod dispatch:
    /// representation decided upstream, coordinator obeys.
    pub policy: DensifyPolicy,
    /// Wire encoding for the fused dense payload traffic (the
    /// allgather control/index traffic stays uncompressed).
    pub wire: WireFormat,
}

impl Default for ExchangeConfig {
    fn default() -> Self {
        Self {
            // segmented pipelined ring: bit-identical results to Ring,
            // allocation-free in steady state on pooled transports
            algo: AllreduceAlgo::RingPipelined,
            fusion_threshold: 128 * 1024 * 1024,
            average: true,
            cache_plans: true,
            policy: DensifyPolicy::AlwaysGather,
            wire: WireFormat::F32,
        }
    }
}

/// Environment variable names used by [`ExchangeConfig::to_env`] /
/// [`ExchangeConfig::from_env`] (the launcher's process-boundary
/// config channel — see `runtime::launcher`).
pub const EXCHANGE_ENV_KEYS: [&str; 6] = [
    "DENSEFOLD_ALGO",
    "DENSEFOLD_FUSION",
    "DENSEFOLD_AVERAGE",
    "DENSEFOLD_CACHE_PLANS",
    "DENSEFOLD_POLICY",
    "DENSEFOLD_WIRE",
];

impl ExchangeConfig {
    /// Serialize the config as `(key, value)` environment pairs so a
    /// launcher can propagate it to re-exec'ed worker processes.  Every
    /// value round-trips through [`ExchangeConfig::from_env`].
    pub fn to_env(&self) -> Vec<(&'static str, String)> {
        let policy = match self.policy {
            DensifyPolicy::Adaptive { dense_above } => format!("adaptive:{dense_above}"),
            other => other.name().to_string(),
        };
        vec![
            ("DENSEFOLD_ALGO", self.algo.name().to_string()),
            ("DENSEFOLD_FUSION", self.fusion_threshold.to_string()),
            ("DENSEFOLD_AVERAGE", (self.average as u8).to_string()),
            ("DENSEFOLD_CACHE_PLANS", (self.cache_plans as u8).to_string()),
            ("DENSEFOLD_POLICY", policy),
            ("DENSEFOLD_WIRE", self.wire.name().to_string()),
        ]
    }

    /// Rebuild a config from the process environment written by
    /// [`ExchangeConfig::to_env`].  Unset or unparseable variables fall
    /// back to the [`Default`] field value, so a worker spawned without
    /// the full set still boots with sane settings.
    pub fn from_env() -> Self {
        let d = Self::default();
        let var = |k: &str| std::env::var(k).ok();
        Self {
            algo: var("DENSEFOLD_ALGO")
                .and_then(|s| AllreduceAlgo::parse(&s))
                .unwrap_or(d.algo),
            fusion_threshold: var("DENSEFOLD_FUSION")
                .and_then(|s| s.parse().ok())
                .unwrap_or(d.fusion_threshold),
            average: var("DENSEFOLD_AVERAGE").map(|s| s != "0").unwrap_or(d.average),
            cache_plans: var("DENSEFOLD_CACHE_PLANS").map(|s| s != "0").unwrap_or(d.cache_plans),
            policy: var("DENSEFOLD_POLICY")
                .and_then(|s| DensifyPolicy::parse(&s))
                .unwrap_or(d.policy),
            wire: var("DENSEFOLD_WIRE")
                .and_then(|s| WireFormat::parse(&s))
                .unwrap_or(d.wire),
        }
    }
}

/// Measured facts about one exchange cycle, the raw material for
/// Fig. 3/5 style reporting.
#[derive(Debug, Clone, Default)]
pub struct ExchangeReport {
    /// Peak accumulated representation size across tensors (bytes) —
    /// the paper's "memory required for accumulation".
    pub peak_accum_bytes: u64,
    /// Total bytes this rank put on the wire.
    pub wire_bytes: u64,
    /// Wall time of the execution phase, microseconds.
    pub exec_us: u64,
    /// Wall time of negotiation, microseconds.
    pub negotiate_us: u64,
    pub n_allreduce_groups: usize,
    pub n_allgather_ops: usize,
    /// Sparse submissions the densification policy converted to dense
    /// this cycle.
    pub n_policy_densified: usize,
    /// Pipelined-ring segment size (elements) the group agreed on for
    /// this cycle — shrinks under memory pressure.
    pub seg_elems: usize,
    /// Memory-pressure level the group agreed on for this cycle (rank
    /// 0's budget reading, broadcast alongside the plan so every rank
    /// degrades identically).
    pub pressure: Pressure,
}

/// Per-rank handle on the exchange engine.
pub struct GradExchange {
    transport: Arc<dyn Transport>,
    rank: usize,
    config: ExchangeConfig,
    pub timeline: Timeline,
    cycle: u64,
    cache: ResponseCache,
    arena: FusionArena,
    policy: PolicyEngine,
    /// Buffer-return pool: f32 backing buffers handed back by the
    /// caller via [`GradExchange::return_grads`], recycled by the
    /// policy-densified path instead of a fresh `to_dense` allocation.
    /// Same free-list discipline (and module) as the transport payload
    /// pools — `crate::transport::pool`.
    dense_pool: Mutex<Vec<Vec<f32>>>,
    dense_pool_counters: PoolCounters,
    /// Memory budget charged by the densify pool and the fusion arena.
    /// Pass the transport's budget to [`GradExchange::with_budget`] so
    /// one ceiling covers all of the process's payload memory.
    budget: Arc<MemoryBudget>,
    /// Segment size (elements) and pressure level agreed at the last
    /// negotiation — rank 0 reads its budget and broadcasts both with
    /// the plan, so the values are identical on every rank by
    /// construction (the pipelined ring requires lockstep segments).
    agreed_seg: usize,
    agreed_level: Pressure,
}

impl GradExchange {
    pub fn new(transport: Arc<dyn Transport>, rank: usize, config: ExchangeConfig) -> Self {
        Self::with_budget(transport, rank, config, Arc::new(MemoryBudget::unlimited()))
    }

    /// Like [`GradExchange::new`] but charging the engine's payload
    /// memory (densify pool, fusion arena) against `budget`.  Use the
    /// same [`MemoryBudget`] the transport was built with
    /// ([`crate::transport::TransportKind::create_with_budget`]) so a
    /// single per-process ceiling covers pools, in-flight frames, and
    /// accumulation buffers together.
    pub fn with_budget(
        transport: Arc<dyn Transport>,
        rank: usize,
        config: ExchangeConfig,
        budget: Arc<MemoryBudget>,
    ) -> Self {
        Self {
            transport,
            rank,
            config,
            timeline: Timeline::new(false),
            cycle: 0,
            cache: ResponseCache::new(),
            arena: FusionArena::new(),
            policy: PolicyEngine::new(config.policy),
            dense_pool: Mutex::new(Vec::new()),
            dense_pool_counters: PoolCounters::default(),
            budget,
            agreed_seg: ring::DEFAULT_SEGMENT_ELEMS,
            agreed_level: Pressure::Ok,
        }
    }

    /// The memory budget this engine charges (unlimited by default).
    pub fn budget(&self) -> &Arc<MemoryBudget> {
        &self.budget
    }

    /// Buffer-return API (the ROADMAP open item): hand a previous
    /// cycle's gradient outputs back to the engine once the optimizer
    /// is done with them.  Dense backing buffers go into a per-engine
    /// free list that the policy-densified path draws from, so the
    /// V×D densification in phase 0 stops allocating once the pool is
    /// warm; sparse outputs are simply dropped.  Purely an
    /// optimization — callers that never return buffers keep the old
    /// allocate-per-cycle behaviour.
    /// With a *limited* budget the returned buffers are what releases
    /// (or re-pools) their charge — a caller that never returns
    /// densified outputs keeps them charged for as long as it holds
    /// them, which is exactly what they cost.
    pub fn return_grads(&mut self, grads: Vec<NamedGrad>) {
        for g in grads {
            if let Grad::Dense(t) = g.grad {
                release_to(&self.dense_pool, &self.dense_pool_counters, &self.budget, t.data);
            }
        }
    }

    /// Counters for the buffer-return densification pool — the
    /// densified-path twin of the transport's
    /// [`Transport::pool_stats`]: flat `allocated` across steady-state
    /// cycles means the phase-0 densification is allocation-free.
    pub fn densify_pool_stats(&self) -> PoolStats {
        self.dense_pool_counters.snapshot()
    }

    /// Densify a sparse submission through the buffer-return pool:
    /// best-fit a returned f32 buffer (allocating only when none
    /// fits — the shared `transport::pool` discipline), zero it,
    /// scatter-add the slices in.
    fn densify_pooled(&mut self, s: &IndexedSlices) -> DenseTensor {
        let elems = s.nrows * s.row_width;
        // acquire_from returns a cleared buffer; resize zero-fills
        let mut buf =
            acquire_from(&self.dense_pool, &self.dense_pool_counters, &self.budget, elems);
        buf.resize(elems, 0.0);
        let mut dense = DenseTensor::from_vec(vec![s.nrows, s.row_width], buf);
        s.add_into(&mut dense);
        dense
    }

    /// Response-cache hit rate so far (1.0 in steady state).
    pub fn cache_hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    /// How many times the fusion arena has been laid out — flat across
    /// steady-state (cache-hit) cycles.
    pub fn arena_relayouts(&self) -> u64 {
        self.arena.relayouts
    }

    pub fn enable_timeline(&mut self) {
        self.timeline = Timeline::new(true);
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn nranks(&self) -> usize {
        self.transport.nranks()
    }

    /// Exchange one cycle of gradients. Every rank must call this with
    /// the same tensors in the same order and representations (the
    /// negotiation verifies and panics on divergence). Returns the
    /// accumulated gradients in submission order.
    pub fn exchange(&mut self, grads: Vec<NamedGrad>) -> (Vec<NamedGrad>, ExchangeReport) {
        let t = self.transport.clone();
        let p = t.nranks();
        let tag0 = self.cycle * TAG_BLOCK;
        self.cycle += 1;
        let mut report = ExchangeReport::default();
        let wire_before = t.stats().bytes;

        // ---- 0: densification policy ----
        // Ask the policy about every sparse submission and densify the
        // ones it routes to the reduce path.  Decisions are in
        // lockstep across ranks (each engine observes only exchange
        // *outputs*, which are identical everywhere), so the readiness
        // fingerprints below still agree; a divergence would be caught
        // by the negotiation's representation check.
        let mut policy_watch: Vec<usize> = Vec::new();
        let grads: Vec<NamedGrad> = if self.config.policy == DensifyPolicy::AlwaysGather {
            grads // zero-overhead default: representation decided upstream
        } else {
            let mut converted = Vec::with_capacity(grads.len());
            for (i, g) in grads.into_iter().enumerate() {
                let out = match g.grad {
                    Grad::Sparse(s) => {
                        let id = name_id(&g.name);
                        if self.config.policy.is_adaptive() {
                            policy_watch.push(i);
                        }
                        // `agreed_level` is the *previous* cycle's
                        // broadcast pressure reading (init Ok), so the
                        // pressure bias is itself in lockstep — a rank
                        // reading its own budget here could diverge.
                        let decision = self.policy.decide_under(
                            id,
                            s.nrows,
                            s.row_width,
                            p,
                            self.config.wire,
                            self.agreed_level,
                        );
                        match decision {
                            Decision::Dense => {
                                report.n_policy_densified += 1;
                                // the V×D buffer comes from the
                                // buffer-return pool (return_grads);
                                // cold engines allocate once per shape
                                let dense = self.densify_pooled(&s);
                                NamedGrad { name: g.name, grad: Grad::Dense(dense) }
                            }
                            Decision::Gather => {
                                NamedGrad { name: g.name, grad: Grad::Sparse(s) }
                            }
                        }
                    }
                    dense => NamedGrad { name: g.name, grad: dense },
                };
                converted.push(out);
            }
            converted
        };

        // ---- 1+2+3: negotiation ----
        let neg_start = self.timeline.now_us();
        let reports: Vec<TensorReport> = grads
            .iter()
            .map(|g| TensorReport {
                id: name_id(&g.name),
                is_sparse: g.grad.is_sparse(),
                nbytes: g.grad.nbytes(),
            })
            .collect();
        // Keys both the response cache and the fusion arena layout.
        let fingerprint = cache::fingerprint_public(&reports);
        let plan = self.negotiate(&reports, tag0);
        report.seg_elems = self.agreed_seg;
        report.pressure = self.agreed_level;
        report.negotiate_us = self.timeline.now_us() - neg_start;
        self.timeline.record_synthetic(
            "negotiation",
            Phase::Negotiate,
            neg_start,
            report.negotiate_us,
            0,
        );

        // ---- 4: execution ----
        let exec_start = self.timeline.now_us();
        let mut out: Vec<Option<NamedGrad>> = grads.iter().map(|_| None).collect();
        let mut slot: Vec<Option<Grad>> = Vec::with_capacity(grads.len());
        let mut names: Vec<String> = Vec::with_capacity(grads.len());
        for g in grads {
            names.push(g.name);
            slot.push(Some(g.grad));
        }
        assert!(
            (plan.entries.len() as u64) <= MAX_PLAN_ENTRIES,
            "plan has {} entries, tag layout hosts {MAX_PLAN_ENTRIES}",
            plan.entries.len()
        );
        // ring algorithms use 2(p-1) tags per invocation; every entry's
        // collective must stay inside its ENTRY_TAGS sub-block
        assert!(2 * (p as u64) <= ENTRY_TAGS, "too many ranks for per-entry tag blocks");
        // Lay out the persistent arena for this plan shape. Keyed by
        // the readiness fingerprint: on the steady-state cache-hit
        // path this is a no-op and the cycle allocates no buffers.
        let arena_grown = self.arena.ensure(fingerprint, plan.entries.len(), |e| {
            let entry = &plan.entries[e];
            match entry.op {
                CollectiveOp::Allreduce => entry
                    .tensors
                    .iter()
                    .map(|&i| match slot[i as usize].as_ref().unwrap() {
                        Grad::Dense(t) => t.data.len(),
                        Grad::Sparse(_) => panic!("plan says dense but slot {i} is sparse"),
                    })
                    .sum(),
                CollectiveOp::Allgather => 0,
            }
        });
        if arena_grown > 0 {
            // Arena growth is plan-determined and identical on every
            // rank, so a budget that cannot host the layout even after
            // the bounded wait is a configuration error (the model
            // simply does not fit): fail fast with the typed message
            // rather than deadlock the exchange.
            if let Err(e) = self.budget.charge(arena_grown, DEFAULT_CHARGE_WAIT) {
                panic!("fusion arena layout exceeds the memory budget: {e}");
            }
        }
        for (entry_idx, entry) in plan.entries.iter().enumerate() {
            let tag = tag0 + DATA_BASE + entry_idx as u64 * ENTRY_TAGS;
            match entry.op {
                CollectiveOp::Allreduce => {
                    let label = if entry.tensors.len() == 1 {
                        names[entry.tensors[0] as usize].clone()
                    } else {
                        format!("fused[{}]", entry.tensors.len())
                    };
                    // take the submitted tensors out of their slots;
                    // their allocations come back to the caller via
                    // the in-place unpack below
                    let mut tensors: Vec<DenseTensor> = entry
                        .tensors
                        .iter()
                        .map(|&i| match slot[i as usize].take().unwrap() {
                            Grad::Dense(t) => t,
                            Grad::Sparse(_) => {
                                panic!("plan says dense but slot {i} is sparse")
                            }
                        })
                        .collect();
                    let bytes = self.arena.region_nbytes(entry_idx);
                    report.peak_accum_bytes = report.peak_accum_bytes.max(bytes);
                    {
                        let refs: Vec<&DenseTensor> = tensors.iter().collect();
                        let arena = &mut self.arena;
                        self.timeline.record(
                            &label,
                            Phase::MemcpyInFusionBuffer,
                            0,
                            || arena.pack_entry(entry_idx, &refs),
                        );
                    }
                    let algo = self.config.algo;
                    let wire = self.config.wire;
                    let rank = self.rank;
                    let t_ref = t.as_ref();
                    let average = self.config.average;
                    let seg = self.agreed_seg;
                    {
                        let region = self.arena.region_mut(entry_idx);
                        self.timeline.record(&label, Phase::Allreduce, bytes, || {
                            collectives::try_allreduce_wire_seg(
                                t_ref, rank, region, algo, tag, wire, seg, None,
                            )
                            .unwrap_or_else(|e| {
                                panic!("allreduce(rank={rank}, {algo:?}, seg={seg}): {e}")
                            });
                            if average {
                                let inv = 1.0 / p as f32;
                                for x in region.iter_mut() {
                                    *x *= inv;
                                }
                            }
                        });
                    }
                    {
                        let arena = &self.arena;
                        self.timeline.record(
                            &label,
                            Phase::MemcpyOutFusionBuffer,
                            0,
                            || arena.unpack_entry(entry_idx, &mut tensors),
                        );
                    }
                    for (&i, tensor) in entry.tensors.iter().zip(tensors) {
                        out[i as usize] = Some(NamedGrad {
                            name: std::mem::take(&mut names[i as usize]),
                            grad: Grad::Dense(tensor),
                        });
                    }
                    report.n_allreduce_groups += 1;
                }
                CollectiveOp::Allgather => {
                    let i = entry.tensors[0] as usize;
                    let name = std::mem::take(&mut names[i]);
                    let mine = match slot[i].take().unwrap() {
                        Grad::Sparse(s) => s,
                        Grad::Dense(_) => panic!("plan says sparse but slot {i} is dense"),
                    };
                    let rank = self.rank;
                    let t_ref = t.as_ref();
                    let mut gathered = self.timeline.record(
                        &name,
                        Phase::Allgather,
                        mine.nbytes() * p as u64,
                        || collectives::allgather_indexed_slices(t_ref, rank, &mine, tag),
                    );
                    report.peak_accum_bytes =
                        report.peak_accum_bytes.max(gathered.nbytes());
                    if self.config.average {
                        gathered.scale(1.0 / p as f32);
                    }
                    out[i] = Some(NamedGrad { name, grad: Grad::Sparse(gathered) });
                    report.n_allgather_ops += 1;
                }
            }
        }
        report.exec_us = self.timeline.now_us() - exec_start;
        report.wire_bytes = t.stats().bytes - wire_before;
        let out: Vec<NamedGrad> = out
            .into_iter()
            .map(|g| g.expect("plan did not cover every tensor"))
            .collect();
        // Feed the policy-managed tensors' *outputs* back into the
        // occupancy history — the same bits on every rank, keeping the
        // engines in lockstep for the next cycle's decisions.
        if self.config.policy.is_adaptive() {
            for &i in &policy_watch {
                let g = &out[i];
                self.policy.observe(name_id(&g.name), &g.grad, p);
            }
        }
        (out, report)
    }

    /// Rank 0's pressure reading and the segment size it implies.
    /// Only the leader consults its budget — the reading rides the
    /// plan broadcast, keeping the degradation lockstep across ranks
    /// (in-process ranks share one budget, so any rank would read the
    /// same value; across processes only the broadcast keeps them
    /// agreed).
    fn leader_degradation(&self) -> (usize, Pressure) {
        let level = self.budget.level();
        if level != Pressure::Ok {
            self.budget.note_degradation();
        }
        (ring::segment_elems_under(level), level)
    }

    /// Readiness report to rank 0, agreement check, plan broadcast.
    /// With `cache_plans`, steady-state cycles take the fast path: a
    /// one-u64 fingerprint agreement instead of the full report+plan
    /// (a representation flip changes the fingerprint, so the hazard
    /// check is preserved — mismatch is a hard error on rank 0).
    ///
    /// Both broadcast paths also carry rank 0's `(segment, pressure)`
    /// degradation reading, which every rank adopts for the execution
    /// phase — the pipelined ring's segment count must agree across
    /// ranks, so a rank privately shrinking its segment under local
    /// pressure would fail the exchange with a length mismatch.
    fn negotiate(&mut self, reports: &[TensorReport], tag0: u64) -> Plan {
        let t = self.transport.clone();
        let t = t.as_ref();
        let p = t.nranks();
        if p == 1 {
            let (seg, level) = self.leader_degradation();
            self.agreed_seg = seg;
            self.agreed_level = level;
            if let Some(plan) = self.config.cache_plans.then(|| self.cache.get(reports)).flatten() {
                return plan;
            }
            let plan = build_plan(reports, self.config.fusion_threshold);
            if self.config.cache_plans {
                self.cache.put(reports, plan.clone());
            }
            return plan;
        }
        if self.config.cache_plans {
            if let Some(plan) = self.cache.get(reports) {
                // fast path: fingerprint agreement + degradation word
                let fp = cache::fingerprint_public(reports);
                if self.rank == 0 {
                    for other in 1..p {
                        let theirs = t.recv(0, other, tag0 + CTL_READY).into_u64();
                        assert_eq!(
                            theirs,
                            vec![fp],
                            "rank {other} diverged from the cached plan fingerprint"
                        );
                    }
                    let (seg, level) = self.leader_degradation();
                    tree::broadcast_payload(
                        t,
                        0,
                        0,
                        Some(Payload::U64(vec![fp, seg as u64, level.as_u64()])),
                        tag0 + CTL_PLAN,
                    );
                    self.agreed_seg = seg;
                    self.agreed_level = level;
                } else {
                    t.send(self.rank, 0, tag0 + CTL_READY, Payload::U64(vec![fp]));
                    let confirm =
                        tree::broadcast_payload(t, self.rank, 0, None, tag0 + CTL_PLAN).into_u64();
                    assert_eq!(confirm[0], fp, "cache fingerprint mismatch from leader");
                    self.agreed_seg = confirm[1] as usize;
                    self.agreed_level = Pressure::from_u64(confirm[2]);
                }
                return plan;
            }
        }
        // encode: [n, (id, sparse, bytes)...]
        let mut msg = vec![reports.len() as u64];
        for r in reports {
            msg.push(r.id);
            msg.push(r.is_sparse as u64);
            msg.push(r.nbytes);
        }
        if self.rank == 0 {
            for other in 1..p {
                let theirs = t.recv(0, other, tag0 + CTL_READY).into_u64();
                assert_eq!(
                    theirs[0] as usize,
                    reports.len(),
                    "rank {other} reported a different tensor count — \
                     ranks have diverged"
                );
                for (i, r) in reports.iter().enumerate() {
                    let id = theirs[1 + 3 * i];
                    let sparse = theirs[2 + 3 * i] != 0;
                    assert_eq!(id, r.id, "rank {other} tensor {i}: name mismatch");
                    assert_eq!(
                        sparse, r.is_sparse,
                        "rank {other} tensor {i}: representation mismatch \
                         (dense vs sparse) — this is the mixed-representation \
                         hazard the accumulation strategy must prevent"
                    );
                }
            }
            let plan = build_plan(reports, self.config.fusion_threshold);
            let (seg, level) = self.leader_degradation();
            // degradation word precedes the plan encoding
            let mut encoded = vec![seg as u64, level.as_u64()];
            encoded.extend(plan.encode());
            tree::broadcast_payload(t, 0, 0, Some(Payload::U64(encoded)), tag0 + CTL_PLAN);
            self.agreed_seg = seg;
            self.agreed_level = level;
            if self.config.cache_plans {
                self.cache.put(reports, plan.clone());
            }
            plan
        } else {
            t.send(self.rank, 0, tag0 + CTL_READY, Payload::U64(msg));
            let encoded =
                tree::broadcast_payload(t, self.rank, 0, None, tag0 + CTL_PLAN).into_u64();
            self.agreed_seg = encoded[0] as usize;
            self.agreed_level = Pressure::from_u64(encoded[1]);
            let plan = Plan::decode(&encoded[2..]);
            if self.config.cache_plans {
                self.cache.put(reports, plan.clone());
            }
            plan
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::testutil::run_ranks;
    use crate::tensor::{DenseTensor, IndexedSlices};

    fn dense_grad(name: &str, data: Vec<f32>) -> NamedGrad {
        let n = data.len();
        NamedGrad {
            name: name.into(),
            grad: Grad::Dense(DenseTensor::from_vec(vec![n], data)),
        }
    }

    fn config(average: bool) -> ExchangeConfig {
        ExchangeConfig {
            algo: AllreduceAlgo::Ring,
            fusion_threshold: 1024,
            average,
            ..Default::default()
        }
    }

    #[test]
    fn dense_exchange_sums_across_ranks() {
        let p = 4;
        let results = run_ranks(p, move |rank, t| {
            let mut ex = GradExchange::new(t, rank, config(false));
            let grads = vec![
                dense_grad("w1", vec![rank as f32; 8]),
                dense_grad("w2", vec![1.0; 3]),
            ];
            let (out, _) = ex.exchange(grads);
            out
        });
        for out in results {
            match &out[0].grad {
                Grad::Dense(t) => assert!(t.data.iter().all(|&x| x == 6.0)), // 0+1+2+3
                _ => panic!(),
            }
            match &out[1].grad {
                Grad::Dense(t) => assert!(t.data.iter().all(|&x| x == 4.0)),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn averaging_divides_by_p() {
        let results = run_ranks(2, move |rank, t| {
            let mut ex = GradExchange::new(t, rank, config(true));
            let (out, _) = ex.exchange(vec![dense_grad("w", vec![4.0, 8.0])]);
            out
        });
        for out in results {
            match &out[0].grad {
                Grad::Dense(t) => assert_eq!(t.data, vec![4.0, 8.0]),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn sparse_exchange_gathers_with_tf_semantics() {
        let p = 3;
        let results = run_ranks(p, move |rank, t| {
            let mut ex = GradExchange::new(t, rank, config(false));
            let grads = vec![NamedGrad {
                name: "embedding".into(),
                grad: Grad::Sparse(IndexedSlices::new(
                    8,
                    2,
                    vec![rank as i32],
                    vec![1.0, 2.0],
                )),
            }];
            ex.exchange(grads)
        });
        for (out, report) in results {
            match &out[0].grad {
                Grad::Sparse(s) => {
                    assert_eq!(s.nslices(), p, "concatenation across ranks");
                    assert_eq!(s.indices, vec![0, 1, 2]);
                }
                _ => panic!("expected sparse output"),
            }
            assert_eq!(report.n_allgather_ops, 1);
            assert_eq!(report.n_allreduce_groups, 0);
        }
    }

    #[test]
    fn mixed_cycle_preserves_order_and_kinds() {
        let results = run_ranks(2, move |rank, t| {
            let mut ex = GradExchange::new(t, rank, config(false));
            let grads = vec![
                dense_grad("a", vec![1.0; 4]),
                NamedGrad {
                    name: "emb".into(),
                    grad: Grad::Sparse(IndexedSlices::new(4, 1, vec![0], vec![1.0])),
                },
                dense_grad("b", vec![2.0; 4]),
            ];
            ex.exchange(grads).0
        });
        for out in results {
            assert_eq!(out[0].name, "a");
            assert_eq!(out[1].name, "emb");
            assert_eq!(out[2].name, "b");
            assert!(!out[0].grad.is_sparse());
            assert!(out[1].grad.is_sparse());
            assert!(!out[2].grad.is_sparse());
        }
    }

    #[test]
    fn report_tracks_gather_blowup() {
        // peak accumulation bytes must grow with p on the sparse path
        let peak_at = |p: usize| {
            let results = run_ranks(p, move |rank, t| {
                let mut ex = GradExchange::new(t, rank, config(false));
                let grads = vec![NamedGrad {
                    name: "embedding".into(),
                    grad: Grad::Sparse(IndexedSlices::new(
                        64,
                        4,
                        vec![1; 8],
                        vec![0.5; 32],
                    )),
                }];
                ex.exchange(grads).1.peak_accum_bytes
            });
            results[0]
        };
        let p2 = peak_at(2);
        let p4 = peak_at(4);
        assert_eq!(p4, 2 * p2, "gather peak must scale linearly with ranks");
    }

    #[test]
    fn multiple_cycles_reuse_engine() {
        let results = run_ranks(2, move |rank, t| {
            let mut ex = GradExchange::new(t, rank, config(false));
            let mut last = 0.0;
            for step in 0..5 {
                let (out, _) =
                    ex.exchange(vec![dense_grad("w", vec![step as f32; 2])]);
                match &out[0].grad {
                    Grad::Dense(t) => last = t.data[0],
                    _ => panic!(),
                }
            }
            last
        });
        assert!(results.iter().all(|&x| x == 8.0)); // 4 + 4
    }

    #[test]
    fn steady_state_exchange_is_allocation_free() {
        // the PR's acceptance property: once the response cache hits
        // and the transport pool is warm, a fused dense exchange cycle
        // allocates zero payload buffers and never relays out the arena.
        // The cycle includes a policy-densified sparse submission whose
        // V×D buffer must come from the buffer-return pool
        // (return_grads), so the densified path is covered too.
        use crate::transport::LocalTransport;
        use std::sync::Arc;

        let p = 4;
        let t = Arc::new(LocalTransport::new(p));
        let mk = |rank| {
            GradExchange::new(
                t.clone(),
                rank,
                ExchangeConfig {
                    fusion_threshold: 1024,
                    policy: DensifyPolicy::AlwaysDense,
                    ..Default::default()
                },
            )
        };
        let engines: Vec<GradExchange> = (0..p).map(mk).collect();
        let run_cycles = |engines: Vec<GradExchange>, n: usize| -> Vec<GradExchange> {
            let handles: Vec<_> = engines
                .into_iter()
                .enumerate()
                .map(|(rank, mut ex)| {
                    std::thread::spawn(move || {
                        for _ in 0..n {
                            let grads = vec![
                                dense_grad("w1", vec![rank as f32; 4096]),
                                dense_grad("w2", vec![1.0; 300]),
                                NamedGrad {
                                    name: "emb".into(),
                                    grad: Grad::Sparse(IndexedSlices::new(
                                        64,
                                        4,
                                        vec![rank as i32; 8],
                                        vec![0.5; 32],
                                    )),
                                },
                            ];
                            let (out, report) = ex.exchange(grads);
                            assert_eq!(report.n_policy_densified, 1);
                            // optimizer done: hand the buffers back
                            ex.return_grads(out);
                        }
                        ex
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        };

        let engines = run_cycles(engines, 3); // negotiate + warm the pools
        let warm = t.pool_stats();
        let warm_allocated = warm.allocated;
        let warm_relayouts: Vec<u64> =
            engines.iter().map(|e| e.arena_relayouts()).collect();

        let engines = run_cycles(engines, 10);
        let steady = t.pool_stats();
        assert_eq!(
            steady.allocated, warm_allocated,
            "steady-state cycles must not allocate payload buffers: {steady:?}"
        );
        assert!(
            steady.recycled > warm_allocated,
            "recycling must carry the steady state: {steady:?}"
        );
        // byte accounting (this PR): the warm pool's byte peak is the
        // steady-state peak — flat bytes are the memory-side twin of
        // the flat `allocated` count — and nothing is evicted when the
        // budget is unlimited and every buffer is under the retain
        // watermark.
        assert_eq!(
            steady.bytes_peak, warm.bytes_peak,
            "steady-state cycles must not grow the pooled-byte peak: {steady:?}"
        );
        assert!(
            steady.bytes_held > 0 && steady.bytes_held <= steady.bytes_peak,
            "pooled bytes must be tracked: {steady:?}"
        );
        assert_eq!(steady.evicted, 0, "nothing to evict without pressure: {steady:?}");
        for (e, before) in engines.iter().zip(warm_relayouts) {
            assert_eq!(e.arena_relayouts(), before, "arena relaid out on a cache hit");
            assert_eq!(e.arena_relayouts(), 1, "one layout at first negotiation");
            let d = e.densify_pool_stats();
            assert_eq!(
                d.allocated, 1,
                "densified path must allocate exactly once (cold cycle): {d:?}"
            );
            assert!(d.recycled >= 10, "densify pool must recycle in steady state: {d:?}");
        }
        assert!(engines[0].cache_hit_rate() > 0.9);
    }

    #[test]
    fn soft_pressure_degrades_segments_but_not_bits() {
        // A budget pinned at Soft (soft watermark 0) makes rank 0
        // broadcast a shrunken pipelined-ring segment and the pools
        // drain on release; the exchanged values must still match the
        // unbudgeted run bit for bit — segment size only re-slices the
        // pipelined ring's messages, never the per-element reduction
        // order.
        use crate::transport::LocalTransport;
        use std::sync::Arc;

        let p = 4;
        let run = |budget: Arc<MemoryBudget>| {
            let t = Arc::new(LocalTransport::with_budget(p, budget.clone()));
            let handles: Vec<_> = (0..p)
                .map(|rank| {
                    let t = t.clone();
                    let budget = budget.clone();
                    std::thread::spawn(move || {
                        let cfg = ExchangeConfig {
                            fusion_threshold: 1024,
                            policy: DensifyPolicy::AlwaysDense,
                            ..Default::default()
                        };
                        let mut ex = GradExchange::with_budget(t, rank, cfg, budget);
                        let mut outs = Vec::new();
                        for step in 0..3 {
                            let grads = vec![
                                dense_grad("w1", vec![(rank + step) as f32; 4096]),
                                NamedGrad {
                                    name: "emb".into(),
                                    grad: Grad::Sparse(IndexedSlices::new(
                                        64,
                                        4,
                                        vec![rank as i32; 8],
                                        vec![0.5; 32],
                                    )),
                                },
                            ];
                            let (out, report) = ex.exchange(grads);
                            let values: Vec<Vec<f32>> = out
                                .iter()
                                .map(|g| match &g.grad {
                                    Grad::Dense(t) => t.data.clone(),
                                    Grad::Sparse(_) => panic!("AlwaysDense output is dense"),
                                })
                                .collect();
                            outs.push((values, report.seg_elems, report.pressure));
                        }
                        outs
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        };

        let reference = run(Arc::new(MemoryBudget::unlimited()));
        let soft_budget = Arc::new(MemoryBudget::with_soft(1 << 30, 0));
        let degraded = run(soft_budget.clone());

        for (r, d) in reference.iter().zip(&degraded) {
            for ((rv, rseg, rlvl), (dv, dseg, dlvl)) in r.iter().zip(d) {
                assert_eq!(rv, dv, "degraded exchange must stay bit-identical");
                assert_eq!(*rseg, ring::DEFAULT_SEGMENT_ELEMS);
                assert_eq!(*rlvl, Pressure::Ok);
                assert_eq!(*dseg, ring::segment_elems_under(Pressure::Soft));
                assert_eq!(*dlvl, Pressure::Soft);
            }
        }
        let stats = soft_budget.stats();
        assert!(stats.degradations > 0, "pressure must be recorded: {stats:?}");
    }

    #[test]
    fn return_grads_without_densify_policy_is_inert() {
        // AlwaysGather never consults the dense pool; returning buffers
        // must be safe and the counters must stay at returned-only
        let results = run_ranks(2, move |rank, t| {
            let mut ex = GradExchange::new(t, rank, config(false));
            let (out, _) = ex.exchange(vec![dense_grad("w", vec![rank as f32; 16])]);
            ex.return_grads(out);
            ex.densify_pool_stats()
        });
        for stats in results {
            assert_eq!(stats.allocated, 0);
            assert_eq!(stats.recycled, 0);
            assert_eq!(stats.returned, 1);
        }
    }

    #[test]
    fn policy_always_dense_densifies_on_first_cycle() {
        let p = 3;
        let results = run_ranks(p, move |rank, t| {
            let cfg = ExchangeConfig {
                policy: DensifyPolicy::AlwaysDense,
                fusion_threshold: 1024,
                average: false,
                ..Default::default()
            };
            let mut ex = GradExchange::new(t, rank, cfg);
            let grads = vec![NamedGrad {
                name: "embedding".into(),
                grad: Grad::Sparse(IndexedSlices::new(4, 2, vec![rank as i32], vec![1.0, 2.0])),
            }];
            ex.exchange(grads)
        });
        for (out, report) in results {
            assert_eq!(report.n_policy_densified, 1);
            assert_eq!(report.n_allreduce_groups, 1);
            assert_eq!(report.n_allgather_ops, 0);
            match &out[0].grad {
                Grad::Dense(d) => {
                    // rows 0..3 each got one rank's [1, 2]
                    assert_eq!(d.data, vec![1., 2., 1., 2., 1., 2., 0., 0.]);
                }
                _ => panic!("policy must have densified"),
            }
        }
    }

    #[test]
    fn adaptive_policy_converges_to_dense_on_dense_stream() {
        // every rank's "sparse" embedding gradient touches every row:
        // cycle 1 gathers (cold start), the engines observe occupancy
        // 1.0 in lockstep, every later cycle densifies on all ranks
        let p = 2;
        let v = 8usize;
        let results = run_ranks(p, move |rank, t| {
            let cfg = ExchangeConfig {
                policy: DensifyPolicy::Adaptive { dense_above: 0.5 },
                fusion_threshold: 1024,
                average: false,
                ..Default::default()
            };
            let mut ex = GradExchange::new(t, rank, cfg);
            let mut densified = Vec::new();
            let mut last_dense = false;
            for _ in 0..4 {
                let grads = vec![NamedGrad {
                    name: "embedding".into(),
                    grad: Grad::Sparse(IndexedSlices::new(
                        v,
                        1,
                        (0..v as i32).collect(),
                        vec![(rank + 1) as f32; v],
                    )),
                }];
                let (out, report) = ex.exchange(grads);
                densified.push(report.n_policy_densified);
                last_dense = !out[0].grad.is_sparse();
            }
            (densified, last_dense)
        });
        for (densified, last_dense) in results {
            assert_eq!(densified, vec![0, 1, 1, 1], "cold-start gather, then dense");
            assert!(last_dense);
        }
    }

    #[test]
    fn adaptive_policy_keeps_gather_on_sparse_stream() {
        let p = 2;
        let results = run_ranks(p, move |rank, t| {
            let cfg = ExchangeConfig {
                policy: DensifyPolicy::Adaptive { dense_above: 0.5 },
                fusion_threshold: 1024,
                average: false,
                ..Default::default()
            };
            let mut ex = GradExchange::new(t, rank, cfg);
            let mut total_densified = 0;
            for _ in 0..4 {
                let grads = vec![NamedGrad {
                    name: "embedding".into(),
                    // 2 distinct rows of 64 globally: occupancy ~0.03
                    grad: Grad::Sparse(IndexedSlices::new(
                        64,
                        1,
                        vec![rank as i32],
                        vec![1.0],
                    )),
                }];
                let (out, report) = ex.exchange(grads);
                total_densified += report.n_policy_densified;
                assert!(out[0].grad.is_sparse());
            }
            total_densified
        });
        assert!(results.iter().all(|&n| n == 0));
    }

    #[test]
    fn fp16_wire_exchange_approximates_f32_and_halves_traffic() {
        let p = 4;
        let len = 1024usize;
        let run_with = |wire: WireFormat| {
            run_ranks(p, move |rank, t| {
                let cfg = ExchangeConfig {
                    wire,
                    fusion_threshold: 1 << 20,
                    average: false,
                    ..Default::default()
                };
                let mut ex = GradExchange::new(t.clone(), rank, cfg);
                let before = t.stats().bytes;
                let (out, _) =
                    ex.exchange(vec![dense_grad("w", vec![0.25 + rank as f32; len])]);
                let data = match &out[0].grad {
                    Grad::Dense(d) => d.data.clone(),
                    _ => panic!(),
                };
                (data, t.stats().bytes - before)
            })
        };
        let f32_runs = run_with(WireFormat::F32);
        let fp16_runs = run_with(WireFormat::Fp16);
        // expected sum: 4*0.25 + 0+1+2+3 = 7.0
        for (data, _) in &fp16_runs {
            for &x in data {
                assert!((x - 7.0).abs() < 0.05, "fp16 result {x}");
            }
        }
        // identical across ranks, bit for bit (lockstep invariant)
        for (data, _) in &fp16_runs[1..] {
            assert_eq!(data, &fp16_runs[0].0);
        }
        // payload traffic roughly halves (control traffic is shared)
        let f32_bytes: u64 = f32_runs.iter().map(|r| r.1).max().unwrap();
        let fp16_bytes: u64 = fp16_runs.iter().map(|r| r.1).max().unwrap();
        assert!(
            (fp16_bytes as f64) < 0.7 * f32_bytes as f64,
            "fp16 {fp16_bytes} vs f32 {f32_bytes}"
        );
    }

    #[test]
    fn timeline_captures_phases() {
        let results = run_ranks(2, move |rank, t| {
            let mut ex = GradExchange::new(t, rank, config(false));
            ex.enable_timeline();
            ex.exchange(vec![dense_grad("w", vec![1.0; 16])]);
            ex.timeline.events.len()
        });
        for n in results {
            assert!(n >= 3, "expected pack/allreduce/unpack events, got {n}");
        }
    }
}
