//! Horovod-timeline-style tracing: per-tensor phase events written as
//! Chrome trace JSON (`chrome://tracing` / Perfetto compatible).  This
//! is how the paper's Fig. 3a/3b were produced; `densefold repro fig3`
//! and `examples/timeline_demo.rs` regenerate equivalent timelines for
//! the two accumulation strategies.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Phases matching Horovod's timeline nomenclature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Negotiate,
    WaitForData,
    MemcpyInFusionBuffer,
    Allreduce,
    Allgather,
    MemcpyOutFusionBuffer,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Negotiate => "NEGOTIATE_ALLREDUCE",
            Phase::WaitForData => "WAIT_FOR_DATA",
            Phase::MemcpyInFusionBuffer => "MEMCPY_IN_FUSION_BUFFER",
            Phase::Allreduce => "ALLREDUCE",
            Phase::Allgather => "ALLGATHER",
            Phase::MemcpyOutFusionBuffer => "MEMCPY_OUT_FUSION_BUFFER",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Event {
    /// Tensor (or fused-group) label.
    pub track: String,
    pub phase: Phase,
    pub start_us: u64,
    pub dur_us: u64,
    pub bytes: u64,
}

/// Event recorder with a wall-clock epoch.  In live mode durations are
/// measured; the simulator records synthetic timestamps directly.
#[derive(Debug)]
pub struct Timeline {
    epoch: Instant,
    pub events: Vec<Event>,
    pub enabled: bool,
}

impl Default for Timeline {
    fn default() -> Self {
        Self::new(true)
    }
}

impl Timeline {
    pub fn new(enabled: bool) -> Self {
        Self { epoch: Instant::now(), events: Vec::new(), enabled }
    }

    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Time a closure and record it under (track, phase).
    pub fn record<R>(
        &mut self,
        track: &str,
        phase: Phase,
        bytes: u64,
        f: impl FnOnce() -> R,
    ) -> R {
        if !self.enabled {
            return f();
        }
        let start = self.now_us();
        let out = f();
        let end = self.now_us();
        self.events.push(Event {
            track: track.to_string(),
            phase,
            start_us: start,
            dur_us: (end - start).max(1),
            bytes,
        });
        out
    }

    /// Record a synthetic event (simulator path).
    pub fn record_synthetic(
        &mut self,
        track: &str,
        phase: Phase,
        start_us: u64,
        dur_us: u64,
        bytes: u64,
    ) {
        if self.enabled {
            self.events.push(Event {
                track: track.to_string(),
                phase,
                start_us,
                dur_us: dur_us.max(1),
                bytes,
            });
        }
    }

    /// Total bytes recorded for a phase (Fig. 3 "what moved where").
    pub fn phase_bytes(&self, phase: Phase) -> u64 {
        self.events
            .iter()
            .filter(|e| e.phase == phase)
            .map(|e| e.bytes)
            .sum()
    }

    /// Total duration of a phase in microseconds.
    pub fn phase_dur_us(&self, phase: Phase) -> u64 {
        self.events
            .iter()
            .filter(|e| e.phase == phase)
            .map(|e| e.dur_us)
            .sum()
    }

    /// Serialize as Chrome trace JSON (array format).
    pub fn to_chrome_trace(&self) -> String {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let items: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                let mut obj = BTreeMap::new();
                obj.insert("name".into(), Json::Str(e.phase.name().into()));
                obj.insert("cat".into(), Json::Str("horovod".into()));
                obj.insert("ph".into(), Json::Str("X".into()));
                obj.insert("ts".into(), Json::Num(e.start_us as f64));
                obj.insert("dur".into(), Json::Num(e.dur_us as f64));
                obj.insert("pid".into(), Json::Num(0.0));
                obj.insert("tid".into(), Json::Str(e.track.clone()));
                let mut args = BTreeMap::new();
                args.insert("bytes".into(), Json::Num(e.bytes as f64));
                obj.insert("args".into(), Json::Obj(args));
                Json::Obj(obj)
            })
            .collect();
        Json::Arr(items).to_string_pretty()
    }

    pub fn write_chrome_trace(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_chrome_trace().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_measures_and_returns() {
        let mut tl = Timeline::new(true);
        let out = tl.record("embedding", Phase::Allreduce, 100, || 42);
        assert_eq!(out, 42);
        assert_eq!(tl.events.len(), 1);
        assert_eq!(tl.events[0].bytes, 100);
        assert!(tl.events[0].dur_us >= 1);
    }

    #[test]
    fn disabled_records_nothing() {
        let mut tl = Timeline::new(false);
        tl.record("x", Phase::Negotiate, 1, || ());
        tl.record_synthetic("x", Phase::Allgather, 0, 5, 9);
        assert!(tl.events.is_empty());
    }

    #[test]
    fn phase_aggregates() {
        let mut tl = Timeline::new(true);
        tl.record_synthetic("a", Phase::Allgather, 0, 10, 100);
        tl.record_synthetic("b", Phase::Allgather, 10, 20, 200);
        tl.record_synthetic("c", Phase::Allreduce, 30, 5, 50);
        assert_eq!(tl.phase_bytes(Phase::Allgather), 300);
        assert_eq!(tl.phase_dur_us(Phase::Allgather), 30);
        assert_eq!(tl.phase_bytes(Phase::Allreduce), 50);
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        use crate::util::json::Json;
        let mut tl = Timeline::new(true);
        tl.record_synthetic("embedding", Phase::Allreduce, 0, 169_000, 139_000_000);
        let json = tl.to_chrome_trace();
        let parsed = Json::parse(&json).unwrap();
        let first = &parsed.as_arr().unwrap()[0];
        assert_eq!(first.get("name").unwrap().as_str(), Some("ALLREDUCE"));
        assert_eq!(
            first.get("args").unwrap().get("bytes").unwrap().as_f64(),
            Some(139_000_000.0)
        );
    }
}
