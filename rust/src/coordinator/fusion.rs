//! Tensor fusion buffer — Horovod's batching of small dense gradients
//! into one collective call (`HOROVOD_FUSION_THRESHOLD`, Listing 2 of
//! the paper's runtime settings: 128 MB on Zenith).
//!
//! Fusion matters because a transformer has hundreds of small tensors
//! (LayerNorm scales, biases): at α ≈ 1.5 µs per message, unfused
//! exchange is latency-bound.  The ablation bench `benches/fusion.rs`
//! quantifies this.

use crate::tensor::DenseTensor;

/// A packed fusion buffer plus the metadata to unpack it.
#[derive(Debug)]
pub struct FusionBuffer {
    pub data: Vec<f32>,
    /// (offset, len, shape) per packed tensor, in pack order.
    layout: Vec<(usize, usize, Vec<usize>)>,
}

impl FusionBuffer {
    /// Pack dense tensors contiguously. Order is preserved exactly.
    pub fn pack(tensors: &[&DenseTensor]) -> Self {
        let total: usize = tensors.iter().map(|t| t.data.len()).sum();
        let mut data = Vec::with_capacity(total);
        let mut layout = Vec::with_capacity(tensors.len());
        for t in tensors {
            layout.push((data.len(), t.data.len(), t.shape.clone()));
            data.extend_from_slice(&t.data);
        }
        Self { data, layout }
    }

    pub fn nbytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    pub fn ntensors(&self) -> usize {
        self.layout.len()
    }

    /// Unpack back into owned tensors (post-allreduce).
    pub fn unpack(&self) -> Vec<DenseTensor> {
        self.layout
            .iter()
            .map(|(off, len, shape)| {
                DenseTensor::from_vec(shape.clone(), self.data[*off..*off + *len].to_vec())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let a = DenseTensor::from_vec(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = DenseTensor::from_vec(vec![3], vec![5., 6., 7.]);
        let c = DenseTensor::scalar(8.0);
        let buf = FusionBuffer::pack(&[&a, &b, &c]);
        assert_eq!(buf.data, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        assert_eq!(buf.ntensors(), 3);
        let out = buf.unpack();
        assert_eq!(out, vec![a, b, c]);
    }

    #[test]
    fn empty_pack() {
        let buf = FusionBuffer::pack(&[]);
        assert_eq!(buf.nbytes(), 0);
        assert!(buf.unpack().is_empty());
    }

    #[test]
    fn mutation_flows_through_unpack() {
        // simulates the allreduce writing reduced values in place
        let a = DenseTensor::from_vec(vec![2], vec![1., 1.]);
        let mut buf = FusionBuffer::pack(&[&a]);
        for x in &mut buf.data {
            *x *= 4.0;
        }
        assert_eq!(buf.unpack()[0].data, vec![4., 4.]);
    }
}
