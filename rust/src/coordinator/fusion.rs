//! Tensor fusion buffer — Horovod's batching of small dense gradients
//! into one collective call (`HOROVOD_FUSION_THRESHOLD`, Listing 2 of
//! the paper's runtime settings: 128 MB on Zenith).
//!
//! Fusion matters because a transformer has hundreds of small tensors
//! (LayerNorm scales, biases): at α ≈ 1.5 µs per message, unfused
//! exchange is latency-bound.  The ablation bench `benches/fusion.rs`
//! quantifies this.
//!
//! Two packing mechanisms:
//!
//! * [`FusionBuffer`] — self-contained pack/unpack that allocates per
//!   cycle (the reference path, kept for tests and one-shot callers).
//! * [`FusionArena`] — a persistent backing buffer laid out once per
//!   plan fingerprint; steady-state cycles copy gradients into the
//!   existing layout and unpack with in-place writes into the caller's
//!   tensors, performing zero allocations after the first cycle.

use crate::tensor::DenseTensor;

/// A packed fusion buffer plus the metadata to unpack it.
#[derive(Debug)]
pub struct FusionBuffer {
    pub data: Vec<f32>,
    /// (offset, len, shape) per packed tensor, in pack order.
    layout: Vec<(usize, usize, Vec<usize>)>,
}

impl FusionBuffer {
    /// Pack dense tensors contiguously. Order is preserved exactly.
    pub fn pack(tensors: &[&DenseTensor]) -> Self {
        let total: usize = tensors.iter().map(|t| t.data.len()).sum();
        let mut data = Vec::with_capacity(total);
        let mut layout = Vec::with_capacity(tensors.len());
        for t in tensors {
            layout.push((data.len(), t.data.len(), t.shape.clone()));
            data.extend_from_slice(&t.data);
        }
        Self { data, layout }
    }

    pub fn nbytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    pub fn ntensors(&self) -> usize {
        self.layout.len()
    }

    /// Unpack back into owned tensors (post-allreduce).
    pub fn unpack(&self) -> Vec<DenseTensor> {
        self.layout
            .iter()
            .map(|(off, len, shape)| {
                DenseTensor::from_vec(shape.clone(), self.data[*off..*off + *len].to_vec())
            })
            .collect()
    }
}

/// Persistent fusion arena: one backing buffer serving every fused
/// dense group of an exchange cycle, laid out per plan fingerprint.
///
/// `ensure` (re)computes the per-entry regions only when the
/// fingerprint changes — i.e. at negotiation time.  On the
/// steady-state cache-hit path the layout is already in place, so
/// `pack_entry` / `unpack_entry` are pure memcpys and the cycle
/// allocates nothing.  The backing buffer never shrinks, so an
/// alternating pair of plans also reaches an allocation-free steady
/// state.
#[derive(Debug, Default)]
pub struct FusionArena {
    data: Vec<f32>,
    /// (offset, elems) per plan entry (allgather entries get (off, 0)).
    regions: Vec<(usize, usize)>,
    key: Option<u64>,
    /// Number of layout (re)builds — flat across steady-state cycles.
    pub relayouts: u64,
}

impl FusionArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Make the arena's layout match the plan identified by `key`:
    /// `n_entries` regions sized by `region_elems(entry_idx)`.  No-op
    /// when `key` matches the current layout.
    ///
    /// Returns the number of *bytes the backing buffer grew by* (0 on
    /// the steady-state no-op path and whenever an old layout already
    /// covers the new one), so the caller can charge the growth
    /// against its [`crate::transport::MemoryBudget`] — the arena
    /// itself is payload memory, exactly like a pooled transport
    /// buffer, and uncounted it would hide the paper's failure mode.
    pub fn ensure(
        &mut self,
        key: u64,
        n_entries: usize,
        region_elems: impl Fn(usize) -> usize,
    ) -> u64 {
        if self.key == Some(key) {
            return 0;
        }
        self.regions.clear();
        let mut off = 0;
        for i in 0..n_entries {
            let n = region_elems(i);
            self.regions.push((off, n));
            off += n;
        }
        let grown = (off.saturating_sub(self.data.len()) * 4) as u64;
        if self.data.len() < off {
            self.data.resize(off, 0.0);
        }
        self.key = Some(key);
        self.relayouts += 1;
        grown
    }

    /// Bytes currently held by the backing buffer.
    pub fn held_bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    /// The mutable backing region for one plan entry (the collective
    /// operates directly on this slice).
    pub fn region_mut(&mut self, entry: usize) -> &mut [f32] {
        let (off, n) = self.regions[entry];
        &mut self.data[off..off + n]
    }

    /// Pack `tensors` contiguously into the entry's region. The
    /// tensors' total length must equal the region size fixed by
    /// `ensure` (the plan and the submission describe the same
    /// tensors).
    pub fn pack_entry(&mut self, entry: usize, tensors: &[&DenseTensor]) {
        let (off, n) = self.regions[entry];
        let mut pos = off;
        for t in tensors {
            self.data[pos..pos + t.data.len()].copy_from_slice(&t.data);
            pos += t.data.len();
        }
        assert_eq!(pos - off, n, "packed tensors do not fill the region");
    }

    /// Unpack the entry's region back into the caller's tensors, in
    /// place — no new tensor allocations.
    pub fn unpack_entry(&self, entry: usize, tensors: &mut [DenseTensor]) {
        let (off, n) = self.regions[entry];
        let mut pos = off;
        for t in tensors.iter_mut() {
            let len = t.data.len();
            t.data.copy_from_slice(&self.data[pos..pos + len]);
            pos += len;
        }
        assert_eq!(pos - off, n, "unpacked tensors do not cover the region");
    }

    /// Region size in bytes for one entry.
    pub fn region_nbytes(&self, entry: usize) -> u64 {
        (self.regions[entry].1 * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let a = DenseTensor::from_vec(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = DenseTensor::from_vec(vec![3], vec![5., 6., 7.]);
        let c = DenseTensor::scalar(8.0);
        let buf = FusionBuffer::pack(&[&a, &b, &c]);
        assert_eq!(buf.data, vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        assert_eq!(buf.ntensors(), 3);
        let out = buf.unpack();
        assert_eq!(out, vec![a, b, c]);
    }

    #[test]
    fn empty_pack() {
        let buf = FusionBuffer::pack(&[]);
        assert_eq!(buf.nbytes(), 0);
        assert!(buf.unpack().is_empty());
    }

    #[test]
    fn mutation_flows_through_unpack() {
        // simulates the allreduce writing reduced values in place
        let a = DenseTensor::from_vec(vec![2], vec![1., 1.]);
        let mut buf = FusionBuffer::pack(&[&a]);
        for x in &mut buf.data {
            *x *= 4.0;
        }
        assert_eq!(buf.unpack()[0].data, vec![4., 4.]);
    }

    #[test]
    fn arena_roundtrip_matches_fusion_buffer() {
        let a = DenseTensor::from_vec(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = DenseTensor::from_vec(vec![3], vec![5., 6., 7.]);
        let c = DenseTensor::scalar(8.0);
        let reference = FusionBuffer::pack(&[&a, &b, &c]);

        let mut arena = FusionArena::new();
        arena.ensure(42, 1, |_| 8);
        arena.pack_entry(0, &[&a, &b, &c]);
        let region: &[f32] = arena.region_mut(0);
        assert_eq!(region, &reference.data[..]);
        assert_eq!(arena.region_nbytes(0), reference.nbytes());

        let mut out = vec![a.clone(), b.clone(), c.clone()];
        for x in out.iter_mut().flat_map(|t| t.data.iter_mut()) {
            *x = 0.0; // prove unpack overwrites in place
        }
        arena.unpack_entry(0, &mut out);
        assert_eq!(out, vec![a, b, c]);
    }

    #[test]
    fn arena_relayout_only_on_key_change() {
        let mut arena = FusionArena::new();
        arena.ensure(1, 2, |i| [4, 6][i]);
        arena.ensure(1, 2, |i| [4, 6][i]);
        assert_eq!(arena.relayouts, 1, "same key must not relayout");
        arena.ensure(2, 1, |_| 10);
        assert_eq!(arena.relayouts, 2);
        // backing never shrinks: region still served without realloc
        assert_eq!(arena.region_mut(0).len(), 10);
    }

    #[test]
    fn arena_multiple_regions_are_disjoint() {
        let x = DenseTensor::from_vec(vec![2], vec![1., 2.]);
        let y = DenseTensor::from_vec(vec![3], vec![3., 4., 5.]);
        let mut arena = FusionArena::new();
        arena.ensure(7, 2, |i| [2, 3][i]);
        arena.pack_entry(0, &[&x]);
        arena.pack_entry(1, &[&y]);
        assert_eq!(arena.region_mut(0).to_vec(), vec![1., 2.]);
        assert_eq!(arena.region_mut(1).to_vec(), vec![3., 4., 5.]);
        // mutate region 1, region 0 untouched
        for v in arena.region_mut(1) {
            *v *= 10.0;
        }
        assert_eq!(arena.region_mut(0).to_vec(), vec![1., 2.]);
        let mut out = vec![DenseTensor::zeros(vec![3])];
        arena.unpack_entry(1, &mut out);
        assert_eq!(out[0].data, vec![30., 40., 50.]);
    }
}
