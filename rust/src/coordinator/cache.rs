//! Response cache — Horovod's optimization for steady-state training:
//! after the first cycle, the set of gradients a transformer submits
//! never changes, so re-negotiating (gather readiness → build plan →
//! broadcast) every step wastes α·log p per cycle.  The cache keys on
//! the full (id, representation, size) fingerprint and replays the
//! plan; any change (a new tensor, a representation flip) is a miss
//! and renegotiates.
//!
//! The fingerprint covers the *representation*, so the hazard the
//! negotiation guards against (rank divergence dense-vs-sparse) cannot
//! slip through the cache: a flip changes the fingerprint, misses, and
//! goes back to the verifying path.

use super::plan::{Plan, TensorReport};
use std::collections::HashMap;

/// FNV-1a over the report list.
fn fingerprint(reports: &[TensorReport]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |x: u64| {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    mix(reports.len() as u64);
    for r in reports {
        mix(r.id);
        mix(r.is_sparse as u64);
        mix(r.nbytes);
    }
    h
}

/// Public fingerprint accessor (used by the exchange fast path for
/// cross-rank agreement).
pub fn fingerprint_public(reports: &[TensorReport]) -> u64 {
    fingerprint(reports)
}

/// Plan cache with hit statistics.
#[derive(Debug, Default)]
pub struct ResponseCache {
    plans: HashMap<u64, Plan>,
    pub hits: u64,
    pub misses: u64,
}

impl ResponseCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Look up the plan for this report set, if cached.
    pub fn get(&mut self, reports: &[TensorReport]) -> Option<Plan> {
        let key = fingerprint(reports);
        match self.plans.get(&key) {
            Some(plan) => {
                self.hits += 1;
                Some(plan.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn put(&mut self, reports: &[TensorReport], plan: Plan) {
        self.plans.insert(fingerprint(reports), plan);
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::plan::build_plan;

    fn reports(sparse_mid: bool) -> Vec<TensorReport> {
        vec![
            TensorReport { id: 1, is_sparse: false, nbytes: 100 },
            TensorReport { id: 2, is_sparse: sparse_mid, nbytes: 500 },
            TensorReport { id: 3, is_sparse: false, nbytes: 100 },
        ]
    }

    #[test]
    fn hit_after_put() {
        let mut cache = ResponseCache::new();
        let r = reports(false);
        assert!(cache.get(&r).is_none());
        let plan = build_plan(&r, 1024);
        cache.put(&r, plan.clone());
        assert_eq!(cache.get(&r), Some(plan));
        assert_eq!(cache.hits, 1);
        assert_eq!(cache.misses, 1);
    }

    #[test]
    fn representation_flip_misses() {
        // the safety property: dense->sparse flip must renegotiate
        let mut cache = ResponseCache::new();
        let dense = reports(false);
        cache.put(&dense, build_plan(&dense, 1024));
        let flipped = reports(true);
        assert!(cache.get(&flipped).is_none(), "flip must miss the cache");
    }

    #[test]
    fn size_change_misses() {
        let mut cache = ResponseCache::new();
        let r1 = reports(false);
        cache.put(&r1, build_plan(&r1, 1024));
        let mut r2 = reports(false);
        r2[0].nbytes = 999; // e.g. dynamic batch changed slice count
        assert!(cache.get(&r2).is_none());
    }

    #[test]
    fn hit_rate_steady_state() {
        let mut cache = ResponseCache::new();
        let r = reports(false);
        cache.put(&r, build_plan(&r, 1024));
        for _ in 0..99 {
            cache.get(&r);
        }
        assert!(cache.hit_rate() > 0.98);
    }
}
