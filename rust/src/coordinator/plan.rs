//! Execution plans — the coordinator's negotiated decision of *which*
//! tensors to exchange, *in what order*, *fused how*, and *with which
//! collective*.  Mirrors Horovod's response cache / coordinator
//! protocol: workers report readiness, rank 0 forms the plan, the plan
//! is broadcast, everyone executes the same sequence.
//!
//! Plans are encoded to flat `u64` vectors for transport (the control
//! plane uses the same [`Transport`] as the data plane, so plan
//! distribution is itself a real message exchange).

/// Collective operation for one plan entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveOp {
    /// Fused dense reduction (one or more tensors packed together).
    Allreduce,
    /// Sparse gather (always a single tensor; Horovod does not fuse
    /// allgather responses).
    Allgather,
}

/// One entry: a fused group (Allreduce) or a single tensor (Allgather).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanEntry {
    pub op: CollectiveOp,
    /// Indices into the negotiated tensor ordering.
    pub tensors: Vec<u32>,
}

/// The negotiated execution plan for one exchange cycle.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Plan {
    pub entries: Vec<PlanEntry>,
}

/// What each rank reports about one ready tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorReport {
    /// Stable id (hash of the tensor name — all ranks agree on names).
    pub id: u64,
    pub is_sparse: bool,
    pub nbytes: u64,
}

/// FNV-1a — stable, dependency-free name hashing for tensor ids.
pub fn name_id(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Build a plan from the (already readiness-validated) tensor reports
/// in rank-0 submission order.  Dense tensors are greedily packed into
/// fusion groups of at most `fusion_threshold` bytes (at least one
/// tensor per group, even if oversized — Horovod semantics: the
/// threshold bounds *additional* packing, it never splits a tensor).
/// Sparse tensors become singleton Allgather entries, closing any open
/// fusion group (ordering is preserved end-to-end).
pub fn build_plan(reports: &[TensorReport], fusion_threshold: u64) -> Plan {
    let mut entries = Vec::new();
    let mut open: Vec<u32> = Vec::new();
    let mut open_bytes = 0u64;
    for (i, r) in reports.iter().enumerate() {
        if r.is_sparse {
            if !open.is_empty() {
                entries.push(PlanEntry {
                    op: CollectiveOp::Allreduce,
                    tensors: std::mem::take(&mut open),
                });
                open_bytes = 0;
            }
            entries.push(PlanEntry {
                op: CollectiveOp::Allgather,
                tensors: vec![i as u32],
            });
        } else {
            if !open.is_empty() && open_bytes + r.nbytes > fusion_threshold {
                entries.push(PlanEntry {
                    op: CollectiveOp::Allreduce,
                    tensors: std::mem::take(&mut open),
                });
                open_bytes = 0;
            }
            open.push(i as u32);
            open_bytes += r.nbytes;
        }
    }
    if !open.is_empty() {
        entries.push(PlanEntry { op: CollectiveOp::Allreduce, tensors: open });
    }
    Plan { entries }
}

impl Plan {
    /// Flatten for broadcast over the transport control plane.
    pub fn encode(&self) -> Vec<u64> {
        let mut out = vec![self.entries.len() as u64];
        for e in &self.entries {
            out.push(match e.op {
                CollectiveOp::Allreduce => 0,
                CollectiveOp::Allgather => 1,
            });
            out.push(e.tensors.len() as u64);
            out.extend(e.tensors.iter().map(|&t| t as u64));
        }
        out
    }

    pub fn decode(data: &[u64]) -> Plan {
        let mut pos = 0;
        let n = data[pos] as usize;
        pos += 1;
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            let op = match data[pos] {
                0 => CollectiveOp::Allreduce,
                1 => CollectiveOp::Allgather,
                x => panic!("bad op code {x}"),
            };
            pos += 1;
            let k = data[pos] as usize;
            pos += 1;
            let tensors = data[pos..pos + k].iter().map(|&t| t as u32).collect();
            pos += k;
            entries.push(PlanEntry { op, tensors });
        }
        Plan { entries }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense(nbytes: u64) -> TensorReport {
        TensorReport { id: 0, is_sparse: false, nbytes }
    }

    fn sparse(nbytes: u64) -> TensorReport {
        TensorReport { id: 0, is_sparse: true, nbytes }
    }

    #[test]
    fn all_dense_single_fused_group() {
        let plan = build_plan(&[dense(10), dense(20), dense(30)], 1000);
        assert_eq!(plan.entries.len(), 1);
        assert_eq!(plan.entries[0].op, CollectiveOp::Allreduce);
        assert_eq!(plan.entries[0].tensors, vec![0, 1, 2]);
    }

    #[test]
    fn threshold_splits_groups() {
        let plan = build_plan(&[dense(60), dense(60), dense(60)], 100);
        assert_eq!(plan.entries.len(), 3, "60+60 > 100 so each is alone");
        let plan = build_plan(&[dense(40), dense(40), dense(40)], 100);
        assert_eq!(plan.entries.len(), 2); // [40+40], [40]
        assert_eq!(plan.entries[0].tensors, vec![0, 1]);
    }

    #[test]
    fn oversized_tensor_never_split() {
        let plan = build_plan(&[dense(10_000)], 100);
        assert_eq!(plan.entries.len(), 1);
        assert_eq!(plan.entries[0].tensors, vec![0]);
    }

    #[test]
    fn sparse_breaks_fusion_and_is_singleton() {
        let plan = build_plan(&[dense(10), sparse(50), dense(10), dense(10)], 1000);
        assert_eq!(plan.entries.len(), 3);
        assert_eq!(plan.entries[0], PlanEntry { op: CollectiveOp::Allreduce, tensors: vec![0] });
        assert_eq!(plan.entries[1], PlanEntry { op: CollectiveOp::Allgather, tensors: vec![1] });
        assert_eq!(plan.entries[2], PlanEntry { op: CollectiveOp::Allreduce, tensors: vec![2, 3] });
    }

    #[test]
    fn order_preserved() {
        let plan = build_plan(
            &[dense(1), dense(1), sparse(1), sparse(1), dense(1)],
            2,
        );
        let flat: Vec<u32> = plan
            .entries
            .iter()
            .flat_map(|e| e.tensors.iter().copied())
            .collect();
        assert_eq!(flat, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let plan = build_plan(
            &[dense(10), sparse(5), dense(700), dense(300), sparse(1)],
            512,
        );
        assert_eq!(Plan::decode(&plan.encode()), plan);
    }

    #[test]
    fn name_id_stable_and_distinct() {
        assert_eq!(name_id("embedding"), name_id("embedding"));
        assert_ne!(name_id("enc0/attn/wq"), name_id("enc0/attn/wk"));
    }
}
