//! # densefold
//!
//! Reproduction of *"Densifying Assumed-sparse Tensors: Improving Memory
//! Efficiency and MPI Collective Performance during Tensor Accumulation
//! for Parallelized Training of Neural Machine Translation Models"*
//! (Cavdar et al., ISC 2019).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — a Horovod-class gradient-exchange runtime:
//!   tensor accumulation strategies ([`tensor::accum`]), MPI-style
//!   collectives ([`collectives`]) over an in-process transport
//!   ([`transport`]), readiness negotiation + tensor fusion + timeline
//!   ([`coordinator`]), a data-parallel trainer ([`train`]), and a
//!   calibrated discrete-event cluster simulator ([`sim`]) that
//!   regenerates the paper's scaling figures at 300-node scale.
//! * **L2 (JAX, build time)** — the tied-embedding transformer whose
//!   training step is AOT-lowered to HLO text (`python/compile/`).
//! * **L1 (Pallas, build time)** — the densify scatter-add kernel (the
//!   paper's operator) and a flash-attention kernel, fused into the same
//!   HLO and executed through [`runtime`] via PJRT.
//!
//! Python never runs on the request path: after `make artifacts` the
//! binary is self-contained.

pub mod collectives;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod train;
pub mod transport;
pub mod util;
