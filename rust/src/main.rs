//! `densefold` — CLI for the Densifying Assumed-sparse Tensors
//! reproduction.
//!
//! ```text
//! densefold train  [--preset P] [--strategy S] [--ranks N] [--steps N]
//!                  [--timeline FILE] [--eval N] [--fusion-mb N] [--algo A]
//! densefold repro  (--fig figN | --all) [--out DIR] [--steps N]
//! densefold info   [--artifacts DIR]
//! ```
//!
//! (The offline registry has no clap; argument parsing is a small
//! hand-rolled substrate — see Cargo.toml note.)

use std::collections::HashMap;
use std::path::PathBuf;

use densefold::collectives::AllreduceAlgo;
use densefold::coordinator::policy::DensifyPolicy;
use densefold::coordinator::ExchangeConfig;
use densefold::transport::{SocketMode, TransportKind, WireFormat};
use densefold::data::CorpusConfig;
use densefold::harness;
use densefold::runtime::launcher;
use densefold::runtime::Manifest;
use densefold::tensor::AccumStrategy;
use densefold::train::{run_session, SessionConfig};
use densefold::util::{human_bytes, human_time};

fn main() {
    // A process exec'd by the multi-process launcher is a worker, not
    // a CLI: run the worker body for its role and exit with the
    // launcher's code contract. Must run before any argument parsing.
    if let Some(env) = launcher::worker_env() {
        std::process::exit(harness::launch::worker_main(&env));
    }
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let mut rest: Vec<String> = args[1..].to_vec();
    // `repro <fig>` positional sugar: `densefold repro threaded` is
    // `densefold repro --fig threaded`
    if cmd == "repro" && rest.first().is_some_and(|a| !a.starts_with("--")) {
        rest.insert(0, "--fig".to_string());
    }
    let flags = parse_flags(&rest);
    let result = match cmd.as_str() {
        "train" => cmd_train(&flags),
        "repro" => cmd_repro(&flags),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "densefold — 'Densifying Assumed-sparse Tensors' (ISC'19) reproduction

commands:
  train   run a live multi-rank data-parallel training session
          --preset tiny|small|base   (default tiny)
          --strategy tf-default|sparse-as-dense|any-dense
          --ranks N      in-process MPI ranks            (default 2)
          --steps N      training steps                  (default 20)
          --eval N       hold out N pairs, report BLEU   (default 0)
          --timeline F   write rank-0 Horovod timeline JSON
          --fusion-mb N  fusion threshold in MB          (default 128)
          --algo ring|ring-pipelined|rd|tree|naive  allreduce algorithm
          --policy always-gather|always-dense|adaptive[:T]|cost-model
                         densification policy            (default always-gather)
          --wire f32|fp16|bf16  dense-path wire format   (default f32)
                         (a 16-bit wire always rides the pipelined
                          ring, overriding --algo for dense traffic)
  repro   regenerate paper tables/figures
          --fig fig3|fig4|fig5|fig6|fig7|fig9|fig11|fig12|validate|equiv|ablation|threaded|chaos|launch|budget|train|hier|scaling
                         (`repro <fig>` also works positionally)
          --all          every figure
          --out DIR      output directory (default results/)
          --steps N      live-run step budget            (default 30)
          threaded mode (real OS-thread ranks, wall-clock; writes
          BENCH_threaded.json):
          --ranks N      threaded ranks                  (default 4)
          --cycles N     exchange cycles per measurement (default 8)
          --layers N     dense layers in the workload    (default 4)
          --layer-kb N   per-layer gradient size in KB   (default 1024)
          --compute-us N backward spin per layer, µs     (default 400)
          --transport shm|socket|local  rank transport   (default shm)
          chaos mode (fault injection + elastic recovery drill; kills
          a rank mid-run and asserts survivors shrink, roll back to
          the checkpoint, and finish bit-identical):
          --ranks N      initial world size              (default 4)
          --cycles N     training steps                  (default 8)
          --kill-rank R  rank to kill, or 'none'         (default 2)
          --kill-cycle N step at which it dies           (default 3)
          --ckpt-every N checkpoint cadence              (default 2)
          --drop P       per-link message drop prob      (default 0)
          --corrupt P    per-link corruption prob        (default 0)
          --delay-us N   per-link delivery delay, µs     (default 0)
          --elems N      gradient vector length          (default 4096)
          --seed N       param/gradient/fault seed       (default 42)
          --transport shm|socket|local  rank transport   (default shm)
          launch mode (multi-process drill: forks worker processes
          over real sockets, proves cross-process bit-identity vs the
          single-process reference, benches the socket data plane into
          BENCH_socket.json, then SIGKILLs a worker and asserts the
          survivors shrink + roll back + finish bit-identical):
          --ranks N      worker processes                (default 4)
          --mode unix|tcp  socket flavour                (default unix)
          --steps N      elastic training steps          (default 8)
          --elems N      gradient vector length          (default 2048)
          --kill-rank R  worker to SIGKILL, or 'none'    (default 2)
          --kill-cycle N step at which it dies           (default 3)
          --ckpt-every N checkpoint cadence              (default 2)
          --cycles N     timed bench cycles per size     (default 6)
          --seed N       param/gradient seed             (default 42)
          budget mode (memory-budget drill: measures the exchange's
          peak working set unbudgeted, reruns the full algo x wire
          grid on local/shm/socket under a fraction of it, and asserts
          bit-identity, peak <= limit, evictions and degradations;
          plus a 100/50/25% throughput ladder and the elastic OOM
          retry/shrink scenario; writes BENCH_budget.json):
          --ranks N      ranks per pass                  (default 4)
          --budget-frac F  budgeted limit as a fraction of the
                         measured peak                   (default 0.25)
          --cycles N     grid cycles per algo x wire     (default 3)
          --elems N      base tensor length (outlier 8x) (default 16384)
          --seed N       gradient seed                   (default 42)
          train mode (end-to-end native training on the threaded
          executor: accumulates --accum micro-batch gradients locally
          in pooled buffers, exchanges once per step through the
          policy/densify/fused-collective path, and hard-asserts the
          determinism gates — (p=k,accum=1)==(p=1,accum=k) and
          local/shm/socket bit-identity; writes BENCH_train.json and
          results/train_loss.csv):
          --ranks N      executor rank threads           (default 2)
          --steps N      optimizer steps                 (default 8)
          --accum N      micro-batches per step          (default 2)
          --wire f32|fp16|bf16  dense-path wire          (default f32)
          --policy always-gather|always-dense|adaptive[:T]|cost-model
          --transport shm|socket|local                   (default shm)
          --strategy tf-default|sparse-as-dense|any-dense
          --vocab N      corpus/model vocabulary         (default 64)
          --d-model N    model hidden width              (default 16)
          --batch N      micro-batch rows                (default 4)
          --lr F         Adam learning rate              (default 0.01)
          --eval N       held-out pairs for BLEU         (default 16)
          --seed N       corpus/param/batch seed         (default 17)
          hier mode (two-level hierarchical exchange drill: proves the
          algo x wire grid and the two-level collective bit-identical
          to the flat reference over a real shm+socket HierTransport,
          checks leader-only fabric byte accounting, runs the one-shot
          alpha-beta calibration into BENCH_calibrate.json, and gates
          the calibrated model against live runs; writes
          BENCH_hier.json):
          --ranks N      world size                      (default 8)
          --nodes N      simulated nodes (blocked topo)  (default 2)
          --spec S       explicit group sizes, e.g. 3+1  (overrides)
          --elems N      gradient vector length          (default 4096)
          --cycles N     timed cycles per bench row      (default 4)
          --transport shm|socket|local  inter-node lane  (default socket)
          scaling mode (replot the paper's weak/strong curves at
          50-1200 simulated ranks from measured alpha-beta constants —
          BENCH_calibrate.json if present, else a live one-shot
          calibration, else assumed Zenith defaults):
          --steps N      DES steps per point             (default 6)
  info    print manifest/artifact summary
          --artifacts DIR                                (default artifacts/)"
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        } else {
            eprintln!("ignoring stray argument '{a}'");
        }
        i += 1;
    }
    out
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(String::as_str).unwrap_or(default)
}

fn artifacts_dir(flags: &HashMap<String, String>) -> PathBuf {
    PathBuf::from(flag(flags, "artifacts", "artifacts"))
}

fn load_manifest(flags: &HashMap<String, String>) -> anyhow::Result<Manifest> {
    Manifest::load(&artifacts_dir(flags))
}

fn parse_strategy(s: &str) -> anyhow::Result<AccumStrategy> {
    AccumStrategy::parse(s).ok_or_else(|| anyhow::anyhow!("bad --strategy '{s}'"))
}

fn parse_transport(s: &str) -> anyhow::Result<TransportKind> {
    TransportKind::parse(s)
        .ok_or_else(|| anyhow::anyhow!("bad --transport '{s}' (local|shm|socket)"))
}

fn cmd_train(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let manifest = load_manifest(flags)?;
    let preset_name = flag(flags, "preset", "tiny").to_string();
    let preset = manifest.preset(&preset_name)?;
    let strategy = parse_strategy(flag(flags, "strategy", "sparse-as-dense"))?;
    let nranks: usize = flag(flags, "ranks", "2").parse()?;
    let steps: usize = flag(flags, "steps", "20").parse()?;
    let eval_pairs: usize = flag(flags, "eval", "0").parse()?;
    let fusion_mb: u64 = flag(flags, "fusion-mb", "128").parse()?;
    let algo = AllreduceAlgo::parse(flag(flags, "algo", "ring-pipelined"))
        .ok_or_else(|| anyhow::anyhow!("bad --algo"))?;
    let policy = DensifyPolicy::parse(flag(flags, "policy", "always-gather"))
        .ok_or_else(|| anyhow::anyhow!("bad --policy"))?;
    let wire = WireFormat::parse(flag(flags, "wire", "f32"))
        .ok_or_else(|| anyhow::anyhow!("bad --wire"))?;
    if wire != WireFormat::F32 && algo != AllreduceAlgo::RingPipelined {
        eprintln!(
            "note: --wire {} forces the ring-pipelined allreduce for dense \
             traffic; --algo {:?} is ignored on that path",
            wire.name(),
            algo
        );
    }
    let timeline_path = flags.get("timeline").cloned();

    let cfg = SessionConfig {
        preset: preset_name.clone(),
        strategy,
        nranks,
        steps,
        exchange: ExchangeConfig {
            algo,
            fusion_threshold: fusion_mb * 1024 * 1024,
            average: true,
            cache_plans: true,
            policy,
            wire,
        },
        corpus: CorpusConfig {
            vocab: preset.config.vocab,
            n_pairs: 2048.max(eval_pairs * 4),
            min_len: 3,
            max_len: (preset.batch.ss - 2).min(12),
            ..Default::default()
        },
        eval_pairs,
        timeline: timeline_path.is_some(),
        seed: flag(flags, "seed", "17").parse()?,
        warmup_steps: (steps / 4).max(10) as u64,
        lr_scale: flag(flags, "lr-scale", "1.0").parse()?,
    };
    println!(
        "training preset={preset_name} strategy={} ranks={nranks} steps={steps} \
         ({} params, batch {} tokens/rank)",
        strategy.name(),
        preset.n_params,
        preset.batch.tokens()
    );
    let result = run_session(&cfg, &manifest)?;
    let losses = result.loss_curve();
    for (i, loss) in losses.iter().enumerate() {
        let s0 = &result.stats[0][i];
        if i % 5 == 0 || i + 1 == losses.len() {
            println!(
                "step {:>4}  loss {:.4}  lr {:.5}  compute {}  exchange {}  peak-accum {}",
                i + 1,
                loss,
                s0.lr,
                human_time(s0.compute_us as f64 / 1e6),
                human_time(s0.exchange.exec_us as f64 / 1e6),
                human_bytes(s0.exchange.peak_accum_bytes),
            );
        }
    }
    println!(
        "done in {}: loss {:.4} -> {:.4}; mean exchange {}; peak accum {}",
        human_time(result.wall_secs),
        losses.first().unwrap(),
        losses.last().unwrap(),
        human_time(result.mean_exchange_us() / 1e6),
        human_bytes(result.peak_accum_bytes()),
    );
    if let Some(b) = result.bleu {
        println!("BLEU on held-out pairs: {b:.1}");
    }
    Ok(())
}

fn cmd_repro(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let out_dir = PathBuf::from(flag(flags, "out", "results"));
    std::fs::create_dir_all(&out_dir)?;
    let steps: usize = flag(flags, "steps", "30").parse()?;
    let all = flags.contains_key("all");
    let which = flag(flags, "fig", "").to_string();
    let want = |name: &str| all || which == name;
    let mut ran = 0;

    if want("fig3") {
        let t = harness::accumulate::fig3_timelines(&out_dir)?;
        harness::emit(&t, &out_dir, "fig3_timelines")?;
        ran += 1;
    }
    if want("fig4") {
        harness::emit(&harness::weak::fig4_sparse_speedup(), &out_dir, "fig4_sparse_speedup")?;
        ran += 1;
    }
    if want("fig5") {
        harness::emit(&harness::accumulate::fig5_space_time(), &out_dir, "fig5_space_time")?;
        harness::emit(&harness::accumulate::fig5_sweep(), &out_dir, "fig5_sweep")?;
        ran += 1;
    }
    if want("fig6") {
        harness::emit(&harness::weak::fig6_compare(), &out_dir, "fig6_weak_compare")?;
        ran += 1;
    }
    if want("fig7") || want("fig8") {
        harness::emit(
            &harness::weak::fig7_fig8_dense_300_nodes(),
            &out_dir,
            "fig7_fig8_weak_dense",
        )?;
        ran += 1;
    }
    if want("fig9") || want("fig10") {
        harness::emit(&harness::strong::fig9_fig10_strong(), &out_dir, "fig9_fig10_strong")?;
        harness::emit(
            &harness::strong::stampede2_large_batch(),
            &out_dir,
            "stampede2_large_batch",
        )?;
        ran += 1;
    }
    if want("fig11") {
        harness::emit(
            &harness::strong::fig11_time_to_solution(),
            &out_dir,
            "fig11_time_to_solution",
        )?;
        ran += 1;
    }
    if want("fig12") {
        let manifest = load_manifest(flags)?;
        let t = harness::quality::fig12_bleu_vs_batch(&manifest, steps.max(60))?;
        harness::emit(&t, &out_dir, "fig12_bleu_vs_batch")?;
        ran += 1;
    }
    if want("equiv") {
        let manifest = load_manifest(flags)?;
        let t = harness::quality::strategy_equivalence(&manifest, steps.min(20))?;
        harness::emit(&t, &out_dir, "strategy_equivalence")?;
        ran += 1;
    }
    if want("ablation") {
        harness::emit(
            &harness::ablation::fusion_threshold_sweep(),
            &out_dir,
            "ablation_fusion_threshold",
        )?;
        harness::emit(
            &harness::ablation::allreduce_algorithm_menu(),
            &out_dir,
            "ablation_allreduce_menu",
        )?;
        harness::emit(
            &harness::ablation::dedup_counterfactual(),
            &out_dir,
            "ablation_dedup_counterfactual",
        )?;
        harness::emit(
            &harness::ablation::hierarchical_vs_flat(),
            &out_dir,
            "ablation_hierarchical",
        )?;
        harness::emit(
            &harness::ablation::policy_wire_grid(),
            &out_dir,
            "ablation_policy_wire_grid",
        )?;
        harness::emit(
            &harness::ablation::wire_weak_scaling_replot(),
            &out_dir,
            "ablation_wire_weak_scaling",
        )?;
        harness::emit(
            &harness::ablation::wire_strong_scaling_replot(),
            &out_dir,
            "ablation_wire_strong_scaling",
        )?;
        ran += 1;
    }
    if want("validate") {
        let manifest = load_manifest(flags)?;
        let t = harness::validate::live_vs_model(&manifest, steps.min(10))?;
        harness::emit(&t, &out_dir, "live_vs_model")?;
        ran += 1;
    }
    if want("chaos") {
        let kill = flag(flags, "kill-rank", "2");
        let opts = harness::chaos::ChaosOpts {
            ranks: flag(flags, "ranks", "4").parse()?,
            cycles: flag(flags, "cycles", "8").parse()?,
            kill_rank: if kill == "none" { None } else { Some(kill.parse()?) },
            kill_cycle: flag(flags, "kill-cycle", "3").parse()?,
            ckpt_every: flag(flags, "ckpt-every", "2").parse()?,
            drop_p: flag(flags, "drop", "0").parse()?,
            corrupt_p: flag(flags, "corrupt", "0").parse()?,
            delay_us: flag(flags, "delay-us", "0").parse()?,
            elems: flag(flags, "elems", "4096").parse()?,
            seed: flag(flags, "seed", "42").parse()?,
            transport: parse_transport(flag(flags, "transport", "shm"))?,
        };
        let t = harness::chaos::chaos_recovery(&opts)?;
        harness::emit(&t, &out_dir, "chaos_recovery")?;
        ran += 1;
    }
    if want("threaded") {
        let opts = harness::threaded::ThreadedOpts {
            ranks: flag(flags, "ranks", "4").parse()?,
            cycles: flag(flags, "cycles", "8").parse()?,
            layers: flag(flags, "layers", "4").parse()?,
            layer_kb: flag(flags, "layer-kb", "1024").parse()?,
            compute_us: flag(flags, "compute-us", "400").parse()?,
            transport: parse_transport(flag(flags, "transport", "shm"))?,
        };
        let (bench, t) = harness::threaded::threaded_bench(&opts);
        bench.emit_json()?;
        bench.write_csv(&out_dir.join("bench_threaded.csv"))?;
        println!("(bench json: BENCH_threaded.json)");
        harness::emit(&t, &out_dir, "threaded_overlap")?;
        ran += 1;
    }
    if want("launch") {
        let kill = flag(flags, "kill-rank", "2");
        // `--transport socket` (the CI spelling) selects the default
        // Unix-domain mode; `--mode tcp` switches to loopback TCP.
        // Under `--all` the flag belongs to the threaded/chaos groups
        // (which accept local/shm/socket), so only reject a non-socket
        // value when launch is the one fig explicitly requested.
        let transport = flag(flags, "transport", "socket");
        anyhow::ensure!(
            all || transport == "socket",
            "repro launch always runs over sockets (got --transport {transport})"
        );
        let opts = harness::launch::LaunchOpts {
            ranks: flag(flags, "ranks", "4").parse()?,
            mode: SocketMode::parse(flag(flags, "mode", "unix"))
                .ok_or_else(|| anyhow::anyhow!("bad --mode (unix|tcp)"))?,
            elems: flag(flags, "elems", "2048").parse()?,
            steps: flag(flags, "steps", "8").parse()?,
            kill_rank: if kill == "none" { None } else { Some(kill.parse()?) },
            kill_cycle: flag(flags, "kill-cycle", "3").parse()?,
            ckpt_every: flag(flags, "ckpt-every", "2").parse()?,
            bench_cycles: flag(flags, "cycles", "6").parse()?,
            seed: flag(flags, "seed", "42").parse()?,
        };
        let (bench, t) = harness::launch::launch_drill(&opts)?;
        bench.emit_json()?;
        bench.write_csv(&out_dir.join("bench_socket.csv"))?;
        println!("(bench json: BENCH_socket.json)");
        harness::emit(&t, &out_dir, "launch_drill")?;
        ran += 1;
    }
    if want("train") {
        let opts = harness::train::TrainOpts {
            ranks: flag(flags, "ranks", "2").parse()?,
            steps: flag(flags, "steps", "8").parse()?,
            accum: flag(flags, "accum", "2").parse()?,
            wire: WireFormat::parse(flag(flags, "wire", "f32"))
                .ok_or_else(|| anyhow::anyhow!("bad --wire (f32|fp16|bf16)"))?,
            policy: DensifyPolicy::parse(flag(flags, "policy", "always-gather"))
                .ok_or_else(|| anyhow::anyhow!("bad --policy"))?,
            transport: parse_transport(flag(flags, "transport", "shm"))?,
            strategy: parse_strategy(flag(flags, "strategy", "sparse-as-dense"))?,
            vocab: flag(flags, "vocab", "64").parse()?,
            d_model: flag(flags, "d-model", "16").parse()?,
            batch_rows: flag(flags, "batch", "4").parse()?,
            lr: flag(flags, "lr", "0.01").parse()?,
            seed: flag(flags, "seed", "17").parse()?,
            eval_pairs: flag(flags, "eval", "16").parse()?,
        };
        let (bench, t, loss) = harness::train::train_bench(&opts)?;
        bench.emit_json()?;
        bench.write_csv(&out_dir.join("bench_train.csv"))?;
        println!("(bench json: BENCH_train.json)");
        harness::emit(&t, &out_dir, "train_summary")?;
        harness::emit(&loss, &out_dir, "train_loss")?;
        ran += 1;
    }
    if want("hier") {
        // `--transport` here picks the *inter-node* lane of the
        // HierTransport; intra-node always rides shm.  Under `--all`
        // the flag may carry another group's value, so fall back to
        // the socket default only when it parses.
        let inter = if all {
            TransportKind::Socket
        } else {
            parse_transport(flag(flags, "transport", "socket"))?
        };
        let opts = harness::hier::HierOpts {
            ranks: flag(flags, "ranks", "8").parse()?,
            nodes: flag(flags, "nodes", "2").parse()?,
            spec: flags.get("spec").cloned(),
            elems: flag(flags, "elems", "4096").parse()?,
            cycles: flag(flags, "cycles", "4").parse()?,
            inter,
        };
        let (bench, t) = harness::hier::hier_drill(&opts)?;
        bench.emit_json()?;
        bench.write_csv(&out_dir.join("bench_hier.csv"))?;
        println!("(bench json: BENCH_hier.json)");
        harness::emit(&t, &out_dir, "hier_exchange")?;
        ran += 1;
    }
    if want("scaling") {
        let (consts, weak, strong) = harness::hier::scaling_replot(steps.min(6) as u32)?;
        harness::emit(&consts, &out_dir, "scaling_constants")?;
        harness::emit(&weak, &out_dir, "scaling_weak_calibrated")?;
        harness::emit(&strong, &out_dir, "scaling_strong_calibrated")?;
        ran += 1;
    }
    if want("budget") {
        let opts = harness::budget::BudgetOpts {
            ranks: flag(flags, "ranks", "4").parse()?,
            budget_frac: flag(flags, "budget-frac", "0.25").parse()?,
            cycles: flag(flags, "cycles", "3").parse()?,
            elems: flag(flags, "elems", "16384").parse()?,
            seed: flag(flags, "seed", "42").parse()?,
        };
        let (bench, t) = harness::budget::budget_drill(&opts)?;
        bench.emit_json()?;
        bench.write_csv(&out_dir.join("bench_budget.csv"))?;
        println!("(bench json: BENCH_budget.json)");
        harness::emit(&t, &out_dir, "memory_budget")?;
        ran += 1;
    }
    anyhow::ensure!(ran > 0, "nothing to run: pass --all or --fig figN");
    println!("\n{ran} experiment group(s) written to {}", out_dir.display());
    Ok(())
}

fn cmd_info(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let manifest = load_manifest(flags)?;
    println!("manifest version {} at {:?}", manifest.version, manifest.dir);
    println!(
        "densify op: T={} D={} V={} ({})",
        manifest.densify.t, manifest.densify.d, manifest.densify.v, manifest.densify.artifact
    );
    for (name, p) in &manifest.presets {
        println!(
            "preset {name}: vocab={} d_model={} layers={}+{} params={} ({}), \
             batch b={} ss={} st={} ({} tokens)",
            p.config.vocab,
            p.config.d_model,
            p.config.n_enc,
            p.config.n_dec,
            p.n_params,
            human_bytes(p.n_params as u64 * 4),
            p.batch.b,
            p.batch.ss,
            p.batch.st,
            p.batch.tokens(),
        );
    }
    Ok(())
}
