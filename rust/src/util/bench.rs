//! Criterion-style micro-benchmark harness (substrate: the offline
//! registry has no criterion).  Warmup, calibrated iteration counts,
//! mean/p50/p95 reporting, and optional CSV output so the paper-figure
//! benches can be replotted.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

use super::stats::Summary;

/// Re-export for bench bodies that need to defeat the optimizer.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub iters: u64,
    pub samples: usize,
}

impl BenchResult {
    fn fmt_ns(ns: f64) -> String {
        crate::util::human_time(ns / 1e9)
    }
}

/// A named group of benchmarks (mirrors criterion's group output).
pub struct Bench {
    pub group: String,
    pub results: Vec<BenchResult>,
    warmup: Duration,
    measure: Duration,
    samples: usize,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // keep totals modest: single-core machine, many benches
        Self {
            group: group.to_string(),
            results: Vec::new(),
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(700),
            samples: 12,
        }
    }

    pub fn with_budget(mut self, warmup_ms: u64, measure_ms: u64, samples: usize) -> Self {
        self.warmup = Duration::from_millis(warmup_ms);
        self.measure = Duration::from_millis(measure_ms);
        self.samples = samples.max(3);
        self
    }

    /// Benchmark `f`, auto-calibrating iterations per sample.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &BenchResult {
        // warmup + calibration
        let mut iters = 1u64;
        let w0 = Instant::now();
        let mut once = {
            let t = Instant::now();
            bb(f());
            t.elapsed()
        };
        while w0.elapsed() < self.warmup {
            let t = Instant::now();
            bb(f());
            once = (once + t.elapsed()) / 2;
        }
        let target = self.measure.as_secs_f64() / self.samples as f64;
        if once.as_secs_f64() > 0.0 {
            iters = ((target / once.as_secs_f64()).ceil() as u64).clamp(1, 1_000_000);
        }
        // measurement
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        self.push_samples(name, samples, iters)
    }

    /// Record externally-measured per-iteration samples (nanoseconds)
    /// under `name` — for wall-clock harnesses (e.g. the threaded
    /// executor) whose iterations cannot be re-driven by a closure.
    /// Reported in the same JSON/CSV schema as [`Bench::bench`].
    pub fn push_samples(&mut self, name: &str, ns: Vec<f64>, iters: u64) -> &BenchResult {
        let s = Summary::from(ns);
        let result = BenchResult {
            name: name.to_string(),
            mean_ns: s.mean,
            p50_ns: s.p50(),
            p95_ns: s.p95(),
            iters,
            samples: s.n(),
        };
        println!(
            "{}/{:<42} mean {:>10}  p50 {:>10}  p95 {:>10}  ({} iters x {} samples)",
            self.group,
            result.name,
            BenchResult::fmt_ns(result.mean_ns),
            BenchResult::fmt_ns(result.p50_ns),
            BenchResult::fmt_ns(result.p95_ns),
            iters,
            s.n(),
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Machine-readable results: `{"group", "results": [{name, iters,
    /// ns_per_iter, p50_ns, p95_ns, samples}]}` — the format the
    /// repo's perf trajectory is tracked in across PRs.
    pub fn to_json(&self) -> String {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut o = BTreeMap::new();
                o.insert("name".into(), Json::Str(r.name.clone()));
                o.insert("iters".into(), Json::Num(r.iters as f64));
                o.insert("ns_per_iter".into(), Json::Num(r.mean_ns));
                o.insert("p50_ns".into(), Json::Num(r.p50_ns));
                o.insert("p95_ns".into(), Json::Num(r.p95_ns));
                o.insert("samples".into(), Json::Num(r.samples as f64));
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("group".into(), Json::Str(self.group.clone()));
        root.insert("results".into(), Json::Arr(results));
        Json::Obj(root).to_string_pretty()
    }

    /// Write the JSON results to `path`.
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json())
    }

    /// Emit `BENCH_<group>.json` in the current directory (bench
    /// binaries call this so every run leaves a comparable record).
    pub fn emit_json(&self) -> std::io::Result<()> {
        self.write_json(std::path::Path::new(&format!("BENCH_{}.json", self.group)))
    }

    /// Write all results as CSV (for EXPERIMENTS.md plots).
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        let mut t = crate::util::csv::Table::new(vec![
            "group", "name", "mean_ns", "p50_ns", "p95_ns", "iters",
        ]);
        for r in &self.results {
            t.push(vec![
                self.group.clone(),
                r.name.clone(),
                format!("{:.1}", r.mean_ns),
                format!("{:.1}", r.p50_ns),
                format!("{:.1}", r.p95_ns),
                r.iters.to_string(),
            ]);
        }
        t.write_csv(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new("test").with_budget(10, 40, 4);
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn ordering_of_costs() {
        let mut b = Bench::new("test").with_budget(10, 60, 4);
        // black_box each element so LLVM cannot close-form the loops
        let small = b
            .bench("small", || (0..100u64).fold(0u64, |a, i| a ^ bb(i)))
            .mean_ns;
        let big = b
            .bench("big", || (0..100_000u64).fold(0u64, |a, i| a ^ bb(i)))
            .mean_ns;
        assert!(big > small * 5.0, "big {big} vs small {small}");
    }

    #[test]
    fn json_output_parses_and_carries_fields() {
        use crate::util::json::Json;
        let mut b = Bench::new("g").with_budget(5, 20, 3);
        b.bench("x/y", || 1 + 1);
        let parsed = Json::parse(&b.to_json()).unwrap();
        assert_eq!(parsed.get("group").unwrap().as_str(), Some("g"));
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.get("name").unwrap().as_str(), Some("x/y"));
        assert!(r.get("ns_per_iter").unwrap().as_f64().unwrap() > 0.0);
        assert!(r.get("iters").unwrap().as_f64().unwrap() >= 1.0);
        let json_path = std::env::temp_dir().join("densefold_bench_test.json");
        b.write_json(&json_path).unwrap();
        let text = std::fs::read_to_string(&json_path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(json_path);
    }

    #[test]
    fn push_samples_reports_summary() {
        let mut b = Bench::new("g").with_budget(5, 20, 3);
        let r = b.push_samples("wall", vec![100.0, 200.0, 300.0], 1);
        assert_eq!(r.mean_ns, 200.0);
        assert_eq!(r.p50_ns, 200.0);
        assert_eq!(r.samples, 3);
        assert_eq!(b.results.len(), 1);
    }

    #[test]
    fn csv_output() {
        let mut b = Bench::new("g").with_budget(5, 20, 3);
        b.bench("x", || 1 + 1);
        let csv_path = std::env::temp_dir().join("densefold_bench_test.csv");
        b.write_csv(&csv_path).unwrap();
        let text = std::fs::read_to_string(&csv_path).unwrap();
        assert!(text.starts_with("group,name,"));
        assert!(text.contains("g,x,"));
        let _ = std::fs::remove_file(csv_path);
    }
}
