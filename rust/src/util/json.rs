//! Minimal JSON parser + writer (substrate: the offline registry has
//! no serde).  Covers the full JSON grammar minus exotic number forms;
//! used for `artifacts/manifest.json` and Chrome-trace output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    // -- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing wants
    /// loud failures, not silent Nones.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- writer ----------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    item.write(out, indent + 1);
                }
                if !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                if !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"presets": {"tiny": {"n_params": 264832, "batch": {"b": 4}}}, "version": 1}"#;
        let v = Json::parse(src).unwrap();
        let text = v.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\t\"ß""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\"ß"));
        let out = Json::Str("a\"b\\c\n".into()).to_string_pretty();
        assert_eq!(Json::parse(&out).unwrap().as_str(), Some("a\"b\\c\n"));
    }

    #[test]
    fn req_reports_key() {
        let v = Json::parse("{}").unwrap();
        assert!(v.req("vocab").unwrap_err().contains("vocab"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
