//! Summary statistics for bench/experiment reporting.

/// Online-free summary of a sample set (keeps the sorted data).
#[derive(Debug, Clone)]
pub struct Summary {
    sorted: Vec<f64>,
    pub mean: f64,
}

impl Summary {
    pub fn from(mut xs: Vec<f64>) -> Self {
        assert!(!xs.is_empty(), "empty sample");
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        Self { sorted: xs, mean }
    }

    pub fn n(&self) -> usize {
        self.sorted.len()
    }

    pub fn min(&self) -> f64 {
        self.sorted[0]
    }

    pub fn max(&self) -> f64 {
        *self.sorted.last().unwrap()
    }

    /// Linear-interpolated quantile, q in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        let pos = q.clamp(0.0, 1.0) * (self.sorted.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.sorted[lo]
        } else {
            let w = pos - lo as f64;
            self.sorted[lo] * (1.0 - w) + self.sorted[hi] * w
        }
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.5)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn stddev(&self) -> f64 {
        let m = self.mean;
        (self.sorted.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.sorted.len() as f64)
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles() {
        let s = Summary::from(vec![4.0, 1.0, 3.0, 2.0, 5.0]);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.quantile(0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_constant_sample() {
        let s = Summary::from(vec![2.0; 10]);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_panics() {
        Summary::from(vec![]);
    }
}
