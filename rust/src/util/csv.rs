//! Tiny CSV + markdown-table writer used by the experiment harness.
//! Every figure reproduction emits both: the CSV for plotting, the
//! markdown for EXPERIMENTS.md.

use std::io::Write;
use std::path::Path;

/// A rectangular results table with named columns.
#[derive(Debug, Clone)]
pub struct Table {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(columns: Vec<S>) -> Self {
        Self {
            columns: columns.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.columns.len(),
            "row width {} != column count {}",
            row.len(),
            self.columns.len()
        );
        self.rows.push(row);
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.columns.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    pub fn to_markdown(&self) -> String {
        let mut out = format!("| {} |\n", self.columns.join(" | "));
        out.push_str(&format!(
            "|{}\n",
            "---|".repeat(self.columns.len())
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut t = Table::new(vec!["p", "time_ms"]);
        t.push(vec!["4", "1.5"]);
        t.push(vec!["8", "2.5"]);
        assert_eq!(t.to_csv(), "p,time_ms\n4,1.5\n8,2.5\n");
        let md = t.to_markdown();
        assert!(md.contains("| p | time_ms |"));
        assert!(md.contains("| 8 | 2.5 |"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = Table::new(vec!["a"]);
        t.push(vec!["1", "2"]);
    }
}
