//! Tiny property-testing driver (substrate: the offline registry has
//! no proptest).  Runs a property over N seeded random cases and, on
//! failure, reports the failing seed so the case is exactly
//! reproducible with `Gen::new(seed)`.

use super::rng::Rng;

/// Random-value generator handed to properties.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.gen_range(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f64() as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_i32_in(&mut self, len: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..len)
            .map(|_| lo + (self.rng.next_u64() % (hi - lo) as u64) as i32)
            .collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.gen_range(0, xs.len())]
    }
}

/// Run `property` over `cases` seeded generators; panic with the seed
/// on the first failure.  Properties signal failure by panicking
/// (assert! et al.) — matching std test style.
pub fn run(cases: u64, property: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            property(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on seed {seed:#x} (case {case}): {msg}");
        }
    }
}

/// Run `f` on a watchdog thread; panic if it does not finish within
/// `secs` — the no-deadlock harness for concurrency tests, where a
/// hang must become a loud failure instead of a stuck CI job.
///
/// If the workload itself panics, that panic is propagated (via
/// `join`) so the real assertion failure is what the test reports.
pub fn with_deadline(secs: u64, label: &str, f: impl FnOnce() + Send + 'static) {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(std::time::Duration::from_secs(secs)) {
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            panic!("{label}: deadlock/timeout after {secs}s")
        }
        // Ok, or Disconnected because the workload panicked before
        // sending — join to propagate the real panic either way
        _ => h.join().expect("workload panicked"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run(50, |g| {
            let n = g.usize_in(0, 100);
            assert!(n < 100);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let result = std::panic::catch_unwind(|| {
            run(50, |g| {
                let n = g.usize_in(0, 100);
                assert!(n < 60, "n={n}"); // will fail on some seed
            });
        });
        let err = result.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string>".into());
        assert!(msg.contains("seed"), "{msg}");
    }

    #[test]
    fn with_deadline_runs_the_workload() {
        let (tx, rx) = std::sync::mpsc::channel();
        with_deadline(30, "trivial", move || tx.send(7).unwrap());
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn with_deadline_propagates_workload_panics() {
        let result = std::panic::catch_unwind(|| {
            with_deadline(30, "panicky", || panic!("inner failure"));
        });
        assert!(result.is_err());
    }

    #[test]
    fn gen_is_reproducible() {
        let mut a = Gen::new(42);
        let mut b = Gen::new(42);
        assert_eq!(a.vec_f32(10, -1.0, 1.0), b.vec_f32(10, -1.0, 1.0));
        assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
    }
}
