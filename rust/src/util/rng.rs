//! Deterministic xorshift64* RNG. All stochastic pieces of the system
//! (corpus generation, simulator jitter) derive from explicit seeds so
//! every experiment row in EXPERIMENTS.md is exactly reproducible.

/// xorshift64* PRNG (Vigna 2016). Not cryptographic; plenty for
/// workload synthesis and jitter.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal multiplicative jitter with the given sigma, mean ~1.
    pub fn lognormal_jitter(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma - 0.5 * sigma * sigma).exp()
    }

    /// Zipf-like rank sampling over [0, n): token frequencies in real
    /// corpora are heavy-tailed, which shapes how often each embedding
    /// row is touched (and therefore the IndexedSlices index pattern).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF on a truncated power law; cheap approximation.
        // x ranges over [1, n]; shift to 0-based ranks.
        let u = self.next_f64();
        let x = ((n as f64).powf(1.0 - s) * u + (1.0 - u)).powf(1.0 / (1.0 - s));
        ((x - 1.0).max(0.0) as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.gen_range(3, 10);
            assert!((3..10).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_head_heavy() {
        let mut r = Rng::new(3);
        let mut counts = vec![0usize; 100];
        for _ in 0..10_000 {
            counts[r.zipf(100, 1.2)] += 1;
        }
        assert!(counts[0] > counts[50] && counts[0] > counts[99]);
    }

    #[test]
    fn jitter_near_one() {
        let mut r = Rng::new(9);
        let n = 5000;
        let mean: f64 =
            (0..n).map(|_| r.lognormal_jitter(0.05)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
    }
}
