//! Small shared utilities: deterministic RNG, statistics, formatting,
//! CSV emission. No external RNG crates — experiments must be exactly
//! reproducible from a seed across platforms.

pub mod bench;
pub mod csv;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Format a byte count the way the paper quotes sizes ("11.4 GB").
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut val = bytes as f64;
    let mut unit = 0;
    while val >= 1000.0 && unit < UNITS.len() - 1 {
        val /= 1000.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.1} {}", val, UNITS[unit])
    }
}

/// Format seconds adaptively (µs/ms/s/h) for report tables.
pub fn human_time(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2} s", secs)
    } else if secs < 7200.0 {
        format!("{:.1} min", secs / 60.0)
    } else {
        format!("{:.1} h", secs / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(11_400_000_000), "11.4 GB");
        assert_eq!(human_bytes(139_000_000), "139.0 MB");
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(0.000_004_3), "4.3 µs");
        assert_eq!(human_time(4.32), "4.32 s");
        assert_eq!(human_time(0.169), "169.0 ms");
        assert!(human_time(30.0 * 24.0 * 3600.0).ends_with("h"));
    }
}
